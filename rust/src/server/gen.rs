//! Generation (prefill/decode) serving on the device core (ISSUE 10
//! tentpole).
//!
//! A [`crate::workloads::generation::GenScenarioSpec`] tenant's request
//! is a little state machine — Prefill → Decode(step k) → Done — that
//! re-submits the next step's kernel graph through the interned
//! zero-clone fast path on each step completion. This module owns that
//! lifecycle plus the memory the paper-era loops never had to model:
//!
//! * **KV ledger** — every admitted request reserves its full cache
//!   footprint (prompt + drawn output tokens) against the scenario's
//!   device KV budget *up front*, so a resident request can always run
//!   to completion and the ledger can never deadlock. Requests that
//!   don't fit park (criticals first may **evict**: resident
//!   best-effort requests are dropped largest-first and later
//!   *recompute* exactly their evicted prefix; criticals are never
//!   evicted).
//! * **Token-level SLOs** — time-to-first-token is recorded at the
//!   first emitted token and inter-token gaps at every kept decode
//!   token, scored against the tenant's `ttft_deadline_us` /
//!   `per_token_us` budgets and threaded through
//!   [`TenantOutcome`](crate::server::online::TenantOutcome).
//! * **Continuous batching** — an optional decode micro-batcher
//!   ([`GenOpts::batch_window_us`]) that coalesces decode-ready
//!   requests of one (model, KV bucket) into shared padded launches,
//!   the comparison point against Miriam's shard padding.
//!
//! [`run_gen`] executes one cell; [`run_gen_grid`] sweeps scenarios ×
//! policies plus the solo-criticals / sequential / batched comparison
//! rows and serializes `BENCH_gen.json` — canonical, host-timing-free,
//! byte-deterministic per seed for any `--threads` value.
//!
//! ```
//! use miriam::gpu::spec::GpuSpec;
//! use miriam::server::gen::{run_gen, GenOpts};
//! use miriam::workloads::generation;
//!
//! let sc = generation::gen_diff(2_000.0);
//! let r = run_gen(&GpuSpec::rtx2060(), &sc, &GenOpts::default()).unwrap();
//! assert_eq!(r.tokens, r.drawn_tokens); // token conservation
//! assert_eq!(r.critical_evictions(), 0); // criticals never evicted
//! ```

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::admission::{
    AdmissionConfig, AdmissionController, AdmissionPolicy, Decision,
};
use crate::coordinator::driver::{initial_arrivals, ArrivalQueue};
use crate::coordinator::stats::merged_quantile;
use crate::gpu::kernel::Criticality;
use crate::gpu::spec::GpuSpec;
use crate::gpu::trace::Trace;
use crate::runtime::json::Json;
use crate::server::online::{
    tenant_json_gen, validate_admission, DeviceCore, TenantOutcome,
};
use crate::workloads::generation::{
    gen_model_by_name, request_seed, GenModelDesc, GenScenarioSpec,
};
use crate::workloads::mdtb::Workload;
use crate::workloads::models::ModelRef;
use crate::workloads::rng::Rng;

/// Engine-request ids at or above this are batched decode groups, not
/// individual generation requests (request `g` uses id `g + 1`).
const BATCH_ID_BASE: u64 = 1 << 40;

/// Largest decode micro-batch one combined launch carries.
const MAX_BATCH: u32 = 8;

/// Default decode micro-batch window (us) for the grid's continuous-
/// batching comparison rows.
pub const GEN_BATCH_WINDOW_US: f64 = 150.0;

/// Configuration of one generation serving run.
#[derive(Debug, Clone)]
pub struct GenOpts {
    /// Coordinator to serve through (any `scheduler_for` name).
    pub scheduler: String,
    /// Admission policy applied to best-effort arrivals (envelopes come
    /// from [`GenScenarioSpec::admission_workload`], so deadline-
    /// feasible admission binds on TTFT).
    pub policy: AdmissionPolicy,
    /// Policy tunables.
    pub admission: AdmissionConfig,
    /// Override the scenario's pinned seed (`None` keeps it).
    pub seed: Option<u64>,
    /// Enable the continuous-batching decode micro-batcher with this
    /// flush window (us). `None` (the default) resubmits each decode
    /// step immediately — Miriam's elastic per-request path.
    pub batch_window_us: Option<f64>,
}

impl Default for GenOpts {
    fn default() -> Self {
        GenOpts {
            scheduler: "miriam".into(),
            policy: AdmissionPolicy::Open,
            admission: AdmissionConfig::default(),
            seed: None,
            batch_window_us: None,
        }
    }
}

/// One generation request's live state (Prefill → Decode(k) → Done).
struct GenReq {
    src: usize,
    crit: bool,
    arrival_us: f64,
    prompt: u32,
    output_len: u32,
    /// Output tokens emitted and kept so far (the KV cache additionally
    /// holds the prompt).
    tokens_done: u32,
    /// Bytes currently reserved in the KV ledger (0 while parked).
    kv_reserved: f64,
    in_flight: bool,
    pending_batch: bool,
    parked: bool,
    /// The in-flight phase was evicted mid-step: discard its output on
    /// completion (a *preempted step*) and park.
    evicted: bool,
    /// Next submission must re-issue the evicted prefix.
    needs_recompute: bool,
    /// The in-flight phase is a recompute prefill (emits no token).
    recomputing: bool,
    deadline_missed: bool,
    ttft_us: f64,
    last_token_us: f64,
}

/// The decode micro-batcher: decode-ready requests wait up to
/// `window_us`, then flush as per-(model, KV bucket) combined launches.
struct Batcher {
    window_us: f64,
    pending: Vec<usize>,
    flush_at: Option<f64>,
}

/// Outcome of one generation serving cell.
#[derive(Debug, Clone)]
pub struct GenReport {
    /// Scenario name.
    pub scenario: String,
    /// Row kind in the grid: `policy`, `solo`, `sequential`, or
    /// `batched`.
    pub kind: String,
    /// GPU preset name.
    pub platform: String,
    /// Coordinator the run served through.
    pub scheduler: String,
    /// Admission policy applied.
    pub policy: AdmissionPolicy,
    /// Seed the run actually used.
    pub seed: u64,
    /// Arrival-generation window (us).
    pub duration_us: f64,
    /// Decode micro-batch window (us; 0 = batching off).
    pub batch_window_us: f64,
    /// Device KV budget (bytes).
    pub kv_budget_bytes: f64,
    /// Peak KV bytes reserved at any instant (must never exceed the
    /// budget).
    pub kv_peak_bytes: f64,
    /// Simulated span until the system drained (us).
    pub span_us: f64,
    /// Simulator events processed.
    pub events: u64,
    /// Output tokens emitted and kept across all tenants.
    pub tokens: u64,
    /// Sum of drawn output lengths over completed requests — token
    /// conservation requires `tokens == drawn_tokens`.
    pub drawn_tokens: u64,
    /// KV evictions performed (best-effort victims only).
    pub evictions: u64,
    /// In-flight steps discarded by eviction (each re-ran after its
    /// recompute).
    pub preempted_steps: u64,
    /// Prefix tokens re-issued by recompute prefills; must equal
    /// [`GenReport::evicted_prefix_tokens`] (the recompute re-issues
    /// exactly the evicted prefix).
    pub recompute_tokens: u64,
    /// Prefix tokens (prompt + kept output) held by requests at the
    /// moment they were evicted.
    pub evicted_prefix_tokens: u64,
    /// Served requests whose recorded TTFT exceeded their end-to-end
    /// latency — structurally impossible, recorded so the gate can
    /// assert it stays 0.
    pub ttft_violations: u64,
    /// Peak best-effort queue depth inside the coordinator.
    pub max_normal_queue: usize,
    /// Critical arrivals whose TTFT deadline was infeasible by the solo
    /// prefill envelope (admitted regardless).
    pub critical_at_risk: u64,
    /// Per-tenant outcomes, in source order.
    pub tenants: Vec<TenantOutcome>,
}

impl GenReport {
    /// Total arrivals seen.
    pub fn offered(&self) -> u64 {
        self.tenants.iter().map(|t| t.offered).sum()
    }

    /// Total arrivals admitted.
    pub fn admitted(&self) -> u64 {
        self.tenants.iter().map(|t| t.admitted).sum()
    }

    /// Total arrivals shed.
    pub fn shed(&self) -> u64 {
        self.tenants.iter().map(|t| t.shed).sum()
    }

    /// Total requests served to completion.
    pub fn served(&self) -> u64 {
        self.tenants.iter().map(|t| t.served).sum()
    }

    /// Shed count over critical tenants (always 0: critical is never
    /// shed).
    pub fn shed_critical(&self) -> u64 {
        self.class_sum(Criticality::Critical, |t| t.shed)
    }

    /// Evictions suffered by critical tenants — the never-evict-
    /// criticals invariant requires this to be 0.
    pub fn critical_evictions(&self) -> u64 {
        self.class_sum(Criticality::Critical, |t| t.evictions)
    }

    /// Total TTFT deadline misses.
    pub fn ttft_misses(&self) -> u64 {
        self.tenants.iter().map(|t| t.ttft_misses).sum()
    }

    /// Total per-token budget misses.
    pub fn token_misses(&self) -> u64 {
        self.tenants.iter().map(|t| t.token_misses).sum()
    }

    fn class_sum(&self, c: Criticality, f: impl Fn(&TenantOutcome) -> u64)
                 -> u64 {
        self.tenants
            .iter()
            .filter(|t| t.criticality == c)
            .map(f)
            .sum()
    }

    /// Critical-class TTFT quantile over all critical tenants (NaN when
    /// nothing was served).
    pub fn crit_ttft_quantile_us(&self, q: f64) -> f64 {
        merged_quantile(
            self.tenants
                .iter()
                .filter(|t| t.criticality == Criticality::Critical)
                .map(|t| t.ttft_us.as_slice()),
            q,
        )
    }

    /// Critical-class TTFT p99 (us).
    pub fn crit_ttft_p99_us(&self) -> f64 {
        self.crit_ttft_quantile_us(0.99)
    }

    /// Inter-token-gap quantile over all tenants (NaN when no decode
    /// token was emitted).
    pub fn inter_token_quantile_us(&self, q: f64) -> f64 {
        merged_quantile(
            self.tenants.iter().map(|t| t.inter_token_us.as_slice()),
            q,
        )
    }

    /// Kept output tokens per second of simulated span.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.span_us <= 0.0 {
            return 0.0;
        }
        self.tokens as f64 / (self.span_us / 1e6)
    }

    /// This cell as a canonical-JSON value (one `cells[]` row of
    /// `BENCH_gen.json`; non-finite quantiles serialize as `null`).
    pub fn to_json_value(&self) -> Json {
        let num = Json::Num;
        let mut m = BTreeMap::new();
        m.insert("scenario".into(), Json::Str(self.scenario.clone()));
        m.insert("kind".into(), Json::Str(self.kind.clone()));
        m.insert("policy".into(), Json::Str(self.policy.name().into()));
        m.insert("scheduler".into(), Json::Str(self.scheduler.clone()));
        m.insert("seed".into(), num(self.seed as f64));
        m.insert("duration_us".into(), num(self.duration_us));
        m.insert("batch_window_us".into(), num(self.batch_window_us));
        m.insert("kv_budget_bytes".into(), num(self.kv_budget_bytes));
        m.insert("kv_peak_bytes".into(), num(self.kv_peak_bytes));
        m.insert("span_us".into(), num(self.span_us));
        m.insert("events".into(), num(self.events as f64));
        m.insert("offered".into(), num(self.offered() as f64));
        m.insert("admitted".into(), num(self.admitted() as f64));
        m.insert("shed".into(), num(self.shed() as f64));
        m.insert("served".into(), num(self.served() as f64));
        m.insert("shed_critical".into(), num(self.shed_critical() as f64));
        m.insert("tokens".into(), num(self.tokens as f64));
        m.insert("drawn_tokens".into(), num(self.drawn_tokens as f64));
        m.insert("tokens_per_sec".into(), num(self.tokens_per_sec()));
        m.insert("evictions".into(), num(self.evictions as f64));
        m.insert("critical_evictions".into(),
                 num(self.critical_evictions() as f64));
        m.insert("preempted_steps".into(), num(self.preempted_steps as f64));
        m.insert("recompute_tokens".into(), num(self.recompute_tokens as f64));
        m.insert("evicted_prefix_tokens".into(),
                 num(self.evicted_prefix_tokens as f64));
        m.insert("ttft_violations".into(), num(self.ttft_violations as f64));
        m.insert("ttft_misses".into(), num(self.ttft_misses() as f64));
        m.insert("token_misses".into(), num(self.token_misses() as f64));
        m.insert("crit_ttft_p50_us".into(),
                 num(self.crit_ttft_quantile_us(0.5)));
        m.insert("crit_ttft_p99_us".into(), num(self.crit_ttft_p99_us()));
        m.insert("inter_token_p99_us".into(),
                 num(self.inter_token_quantile_us(0.99)));
        m.insert("max_normal_queue".into(), num(self.max_normal_queue as f64));
        m.insert("critical_at_risk".into(), num(self.critical_at_risk as f64));
        m.insert(
            "tenants".into(),
            Json::Arr(self.tenants.iter().map(tenant_json_gen).collect()),
        );
        Json::Obj(m)
    }
}

/// Graph-cache key: (model index, prefill?, bucketed length, batch).
type GraphKey = (usize, bool, u32, u32);

/// The live state of one generation serving run.
struct GenSim<'a> {
    sc: &'a GenScenarioSpec,
    base_wl: Workload,
    seed: u64,
    core: DeviceCore,
    ctrl: AdmissionController,
    arrivals: ArrivalQueue,
    /// Distinct generation models of the scenario.
    models: Vec<GenModelDesc>,
    /// Source index → index into `models`.
    src_model: Vec<usize>,
    graphs: BTreeMap<GraphKey, (ModelRef, Arc<Vec<u32>>)>,
    reqs: Vec<GenReq>,
    /// Per-source admitted-request ordinals (output-draw seeding).
    ordinals: Vec<u64>,
    /// Requests currently holding KV reservations, by request index.
    resident: BTreeSet<usize>,
    /// Requests waiting for KV space, ascending request index.
    parked: Vec<usize>,
    kv_used: f64,
    kv_peak: f64,
    tenants: Vec<TenantOutcome>,
    batcher: Option<Batcher>,
    batches: HashMap<u64, Vec<usize>>,
    next_batch_id: u64,
    tokens: u64,
    drawn_tokens: u64,
    evictions: u64,
    preempted_steps: u64,
    recompute_tokens: u64,
    evicted_prefix_tokens: u64,
    ttft_violations: u64,
}

impl<'a> GenSim<'a> {
    fn new(gpu: &GpuSpec, sc: &'a GenScenarioSpec, opts: &GenOpts,
           trace: bool) -> Result<Self, String> {
        validate_admission(&opts.admission)?;
        sc.validate()?;
        if let Some(w) = opts.batch_window_us {
            if !(w > 0.0) || !w.is_finite() {
                return Err("batch_window_us must be positive and finite"
                    .into());
            }
        }
        let seed = opts.seed.unwrap_or(sc.seed);
        let mut base_wl = sc.base_workload();
        base_wl.seed = seed;
        let core = DeviceCore::new_traced(gpu, &base_wl, &opts.scheduler,
                                          trace)?;
        let mut adm_wl = sc.admission_workload();
        adm_wl.seed = seed;
        let ctrl = AdmissionController::new(
            opts.policy,
            opts.admission.clone(),
            &adm_wl,
            core.spec(),
            core.params(),
        );
        let mut rng = Rng::new(seed);
        let arrivals = initial_arrivals(&base_wl, &mut rng);

        let mut models: Vec<GenModelDesc> = Vec::new();
        let mut src_model = Vec::with_capacity(sc.sources.len());
        for s in &sc.sources {
            let idx = match models.iter().position(|m| m.name == s.model) {
                Some(i) => i,
                None => {
                    models.push(gen_model_by_name(&s.model).ok_or_else(
                        || format!("unknown gen model {}", s.model),
                    )?);
                    models.len() - 1
                }
            };
            src_model.push(idx);
        }

        let tenants = (0..sc.sources.len())
            .map(|i| {
                let s = &sc.sources[i];
                TenantOutcome {
                    source: i,
                    label: sc.tenant_label(i),
                    model: s.model.clone(),
                    criticality: s.criticality,
                    offered: 0,
                    admitted: 0,
                    shed: 0,
                    served: 0,
                    deadline_misses: 0,
                    requeues: 0,
                    lost: 0,
                    retries: 0,
                    hedges: 0,
                    hedge_wins: 0,
                    cancelled: 0,
                    latencies_us: Vec::new(),
                    tokens: 0,
                    ttft_misses: 0,
                    token_misses: 0,
                    evictions: 0,
                    preempted_steps: 0,
                    ttft_us: Vec::new(),
                    inter_token_us: Vec::new(),
                }
            })
            .collect();

        let mut sim = GenSim {
            sc,
            base_wl,
            seed,
            core,
            ctrl,
            arrivals,
            models,
            src_model,
            graphs: BTreeMap::new(),
            reqs: Vec::new(),
            ordinals: vec![0; sc.sources.len()],
            resident: BTreeSet::new(),
            parked: Vec::new(),
            kv_used: 0.0,
            kv_peak: 0.0,
            tenants,
            batcher: opts.batch_window_us.map(|w| Batcher {
                window_us: w,
                pending: Vec::new(),
                flush_at: None,
            }),
            batches: HashMap::new(),
            next_batch_id: BATCH_ID_BASE,
            tokens: 0,
            drawn_tokens: 0,
            evictions: 0,
            preempted_steps: 0,
            recompute_tokens: 0,
            evicted_prefix_tokens: 0,
            ttft_violations: 0,
        };
        // Seed the prefill-graph cache with the base workload's shared
        // model Arcs + the core's per-source interned ids, so a
        // recompute that lands on the original prompt bucket reuses the
        // exact graph the first prefill ran.
        for (src, s) in sim.sc.sources.iter().enumerate() {
            let mi = sim.src_model[src];
            let bucket = sim.models[mi].prompt_bucketed(s.prompt_len);
            let key = (mi, true, bucket, 1);
            if !sim.graphs.contains_key(&key) {
                let model = sim.base_wl.sources[src].model.clone();
                let ids = sim.core.source_name_ids(src);
                sim.graphs.insert(key, (model, ids));
            }
        }
        Ok(sim)
    }

    fn eng_id(g: usize) -> u64 {
        debug_assert!((g as u64) < BATCH_ID_BASE - 1);
        g as u64 + 1
    }

    fn footprint(&self, g: usize) -> f64 {
        let r = &self.reqs[g];
        self.models[self.src_model[r.src]]
            .kv_bytes(r.prompt + r.output_len)
    }

    /// The (graph, interned ids) for a phase, built and interned on
    /// first use; hot decode steps are pure cache hits (zero alloc).
    fn graph_for(&mut self, mi: usize, prefill: bool, len: u32, batch: u32)
                 -> (ModelRef, Arc<Vec<u32>>) {
        let m = &self.models[mi];
        let bucket = if prefill {
            m.prompt_bucketed(len)
        } else {
            m.kv_bucketed(len)
        };
        let key = (mi, prefill, bucket, batch);
        if let Some(hit) = self.graphs.get(&key) {
            return hit.clone();
        }
        let desc = if prefill {
            m.prefill_graph(bucket)
        } else {
            m.decode_graph_batched(bucket, batch)
        };
        let ids = self.core.intern_model(&desc);
        let entry = (Arc::new(desc), ids);
        self.graphs.insert(key, entry.clone());
        entry
    }

    /// Reserve `g`'s full KV footprint. Criticals under pressure evict
    /// resident best-effort requests (largest reservation first, ties
    /// to the oldest; never criticals, never themselves) until the
    /// reservation fits or no victim remains.
    fn try_reserve(&mut self, g: usize, _now: f64) -> bool {
        let need = self.footprint(g);
        let budget = self.sc.kv_budget_bytes;
        if self.kv_used + need > budget && self.reqs[g].crit {
            while self.kv_used + need > budget {
                let victim = self
                    .resident
                    .iter()
                    .copied()
                    .filter(|&v| !self.reqs[v].crit)
                    .max_by(|&a, &b| {
                        self.reqs[a]
                            .kv_reserved
                            .total_cmp(&self.reqs[b].kv_reserved)
                            .then(b.cmp(&a)) // ties: oldest (smallest id)
                    });
                match victim {
                    Some(v) => self.evict(v),
                    None => break,
                }
            }
        }
        if self.kv_used + need <= budget {
            self.kv_used += need;
            self.kv_peak = self.kv_peak.max(self.kv_used);
            debug_assert!(self.kv_used <= budget + 1e-6);
            self.reqs[g].kv_reserved = need;
            self.resident.insert(g);
            true
        } else {
            false
        }
    }

    /// Evict resident best-effort request `v`: release its reservation
    /// and mark it for recompute. If a step is in flight its output is
    /// discarded on completion (a preempted step); a batch-pending
    /// victim leaves the pending queue immediately.
    fn evict(&mut self, v: usize) {
        debug_assert!(!self.reqs[v].crit, "evicted a critical request");
        let (src, reserved, prefix) = {
            let r = &mut self.reqs[v];
            let reserved = r.kv_reserved;
            r.kv_reserved = 0.0;
            r.needs_recompute = true;
            (r.src, reserved, (r.prompt + r.tokens_done) as u64)
        };
        self.kv_used -= reserved;
        self.evicted_prefix_tokens += prefix;
        self.evictions += 1;
        self.tenants[src].evictions += 1;
        self.resident.remove(&v);
        if self.reqs[v].pending_batch {
            self.reqs[v].pending_batch = false;
            if let Some(b) = self.batcher.as_mut() {
                b.pending.retain(|&p| p != v);
            }
            self.park(v);
        } else if self.reqs[v].in_flight {
            self.reqs[v].evicted = true; // parks at completion
        } else {
            // Resident but neither queued nor in flight cannot happen:
            // every resident request always has exactly one phase
            // pending or in flight.
            unreachable!("evicted request {v} has no pending phase");
        }
    }

    fn park(&mut self, g: usize) {
        self.reqs[g].parked = true;
        match self.parked.binary_search(&g) {
            Ok(_) => {}
            Err(pos) => self.parked.insert(pos, g),
        }
    }

    /// After any KV release: admit parked requests (ascending request
    /// id; parked criticals may evict) and submit their next phase.
    fn unpark_pass(&mut self, now: f64) {
        let mut i = 0;
        while i < self.parked.len() {
            let g = self.parked[i];
            if self.try_reserve(g, now) {
                // A parked critical's reservation may have evicted a
                // pending-batch victim, which parks it mid-list and can
                // shift `g`'s position — re-locate `g` by value.
                let pos = self
                    .parked
                    .binary_search(&g)
                    .expect("reserved request missing from parked list");
                self.parked.remove(pos);
                self.reqs[g].parked = false;
                self.submit_restart(g, now);
            } else {
                i += 1;
            }
        }
    }

    /// Submit the (re)start phase of a freshly unparked request: the
    /// initial prefill if it never emitted a token, otherwise the
    /// recompute prefill over exactly its evicted prefix.
    fn submit_restart(&mut self, g: usize, now: f64) {
        let (src, crit, prompt, tokens_done, needs_recompute) = {
            let r = &self.reqs[g];
            (r.src, r.crit, r.prompt, r.tokens_done, r.needs_recompute)
        };
        if needs_recompute {
            self.reqs[g].needs_recompute = false;
            self.recompute_tokens += (prompt + tokens_done) as u64;
        }
        if tokens_done == 0 {
            // Initial prefill (first attempt or post-eviction re-run):
            // the exact per-source path `DeviceCore::submit` uses.
            self.core.submit(&self.base_wl, src, now, Self::eng_id(g));
            self.reqs[g].in_flight = true;
        } else {
            let mi = self.src_model[src];
            self.reqs[g].recomputing = true;
            let (model, ids) =
                self.graph_for(mi, true, prompt + tokens_done, 1);
            self.core.submit_model(
                &model,
                &ids,
                src,
                if crit { Criticality::Critical } else { Criticality::Normal },
                now,
                Self::eng_id(g),
            );
            self.reqs[g].in_flight = true;
        }
    }

    /// One arrival from `src` at time `t`: admission, output-length
    /// draw, KV reservation (or parking), initial prefill submit.
    fn arrival(&mut self, src: usize, t: f64) {
        self.tenants[src].offered += 1;
        match self.ctrl.decide(src, t) {
            Decision::Admitted => {}
            Decision::Shed(_) => {
                // Gen sources are open-loop (validated), so a shed
                // arrival is simply dropped — no backoff retry.
                self.tenants[src].shed += 1;
                return;
            }
        }
        self.tenants[src].admitted += 1;
        let ord = self.ordinals[src];
        self.ordinals[src] += 1;
        let spec = &self.sc.sources[src];
        let output_len =
            spec.draw_output_len(request_seed(self.seed, src, ord));
        let g = self.reqs.len();
        self.reqs.push(GenReq {
            src,
            crit: spec.criticality == Criticality::Critical,
            arrival_us: t,
            prompt: spec.prompt_len,
            output_len,
            tokens_done: 0,
            kv_reserved: 0.0,
            in_flight: false,
            pending_batch: false,
            parked: false,
            evicted: false,
            needs_recompute: false,
            recomputing: false,
            deadline_missed: false,
            ttft_us: f64::NAN,
            last_token_us: t,
        });
        if self.try_reserve(g, t) {
            self.core.submit(&self.base_wl, src, t, Self::eng_id(g));
            self.reqs[g].in_flight = true;
        } else {
            self.park(g);
        }
    }

    /// A phase of request `g` completed at `now`: emit/discard its
    /// token and drive the state machine to the next phase.
    fn on_phase_done(&mut self, g: usize, now: f64) {
        self.reqs[g].in_flight = false;
        if self.reqs[g].evicted {
            // The step ran against an evicted cache: discard its
            // output; the recompute (queued behind the parking) covers
            // exactly the kept prefix.
            self.reqs[g].evicted = false;
            self.preempted_steps += 1;
            self.tenants[self.reqs[g].src].preempted_steps += 1;
            self.park(g);
            return;
        }
        if self.reqs[g].recomputing {
            self.reqs[g].recomputing = false;
            self.submit_decode_or_enqueue(g, now);
            return;
        }
        let first = self.reqs[g].tokens_done == 0;
        self.reqs[g].tokens_done += 1;
        self.emit_token(g, now, first);
        if self.reqs[g].tokens_done == self.reqs[g].output_len {
            self.complete(g, now);
        } else {
            self.submit_decode_or_enqueue(g, now);
        }
    }

    fn emit_token(&mut self, g: usize, now: f64, first: bool) {
        let r = &mut self.reqs[g];
        let t = &mut self.tenants[r.src];
        let spec = &self.sc.sources[r.src];
        t.tokens += 1;
        self.tokens += 1;
        if first {
            r.ttft_us = now - r.arrival_us;
            t.ttft_us.push(r.ttft_us);
            if spec.ttft_deadline_us.is_some_and(|d| r.ttft_us > d) {
                t.ttft_misses += 1;
                r.deadline_missed = true;
            }
        } else {
            let gap = now - r.last_token_us;
            t.inter_token_us.push(gap);
            if spec.per_token_us.is_some_and(|d| gap > d) {
                t.token_misses += 1;
                r.deadline_missed = true;
            }
        }
        r.last_token_us = now;
    }

    fn complete(&mut self, g: usize, now: f64) {
        let (src, lat, ttft, missed, output_len, reserved) = {
            let r = &self.reqs[g];
            (r.src, now - r.arrival_us, r.ttft_us, r.deadline_missed,
             r.output_len, r.kv_reserved)
        };
        let t = &mut self.tenants[src];
        t.served += 1;
        t.latencies_us.push(lat);
        if missed {
            t.deadline_misses += 1;
        }
        if ttft > lat + 1e-9 {
            self.ttft_violations += 1;
        }
        self.drawn_tokens += output_len as u64;
        self.ctrl.on_served(src);
        self.kv_used -= reserved;
        self.reqs[g].kv_reserved = 0.0;
        self.resident.remove(&g);
        self.unpark_pass(now);
    }

    fn submit_decode_or_enqueue(&mut self, g: usize, now: f64) {
        if let Some(b) = self.batcher.as_mut() {
            self.reqs[g].pending_batch = true;
            if b.pending.is_empty() {
                b.flush_at = Some(now + b.window_us);
            }
            b.pending.push(g);
        } else {
            self.reqs[g].in_flight = true;
            self.submit_decode(g, now, 1, None);
        }
    }

    /// Submit one decode step for `g` (`batch == 1`), or the combined
    /// step for a whole flush chunk (`batch > 1`, `rep` given).
    fn submit_decode(&mut self, g: usize, now: f64, batch: u32,
                     rep: Option<u64>) {
        let (src, crit, kv_len) = {
            let r = &self.reqs[g];
            (r.src, r.crit, r.prompt + r.tokens_done)
        };
        let mi = self.src_model[src];
        let (model, ids) = self.graph_for(mi, false, kv_len, batch);
        let id = rep.unwrap_or_else(|| Self::eng_id(g));
        self.core.submit_model(
            &model,
            &ids,
            src,
            if crit { Criticality::Critical } else { Criticality::Normal },
            now,
            id,
        );
    }

    /// The micro-batcher's grouping key for request `g`: (model index,
    /// current KV bucket).
    fn batch_key(&self, g: usize) -> (usize, u32) {
        let r = &self.reqs[g];
        let mi = self.src_model[r.src];
        (mi, self.models[mi].kv_bucketed(r.prompt + r.tokens_done))
    }

    /// Flush the decode micro-batcher: group pending requests by
    /// (model, KV bucket), submit chunks of up to [`MAX_BATCH`] as one
    /// combined launch each (singletons go the plain path). A chunk's
    /// class is critical iff any member is.
    fn flush(&mut self, now: f64) {
        let b = match self.batcher.as_mut() {
            Some(b) => b,
            None => return,
        };
        b.flush_at = None;
        let mut pending = std::mem::take(&mut b.pending);
        pending.sort_unstable_by_key(|&g| {
            let (mi, bucket) = self.batch_key(g);
            (mi, bucket, g)
        });
        let mut i = 0;
        while i < pending.len() {
            let (mi, bucket) = self.batch_key(pending[i]);
            let mut j = i + 1;
            while j < pending.len()
                && j - i < MAX_BATCH as usize
                && self.batch_key(pending[j]) == (mi, bucket)
            {
                j += 1;
            }
            let chunk: Vec<usize> = pending[i..j].to_vec();
            for &g in &chunk {
                self.reqs[g].pending_batch = false;
                self.reqs[g].in_flight = true;
            }
            if chunk.len() == 1 {
                self.submit_decode(chunk[0], now, 1, None);
            } else {
                let batch = chunk.len() as u32;
                let crit = chunk.iter().any(|&g| self.reqs[g].crit);
                let src = self.reqs[chunk[0]].src;
                let (model, ids) = self.graph_for(mi, false, bucket, batch);
                let rep = self.next_batch_id;
                self.next_batch_id += 1;
                self.core.submit_model(
                    &model,
                    &ids,
                    src,
                    if crit {
                        Criticality::Critical
                    } else {
                        Criticality::Normal
                    },
                    now,
                    rep,
                );
                self.batches.insert(rep, chunk);
            }
            i = j;
        }
    }

    /// Drive the run to completion (arrivals exhausted, engine idle,
    /// batcher empty, nothing parked).
    fn run(&mut self) -> Result<(), String> {
        let mut done_buf: Vec<(u64, f64)> = Vec::new();
        loop {
            let t_arr = self.arrivals.peek().map(|(t, _)| t);
            let t_ev = self.core.next_event_time();
            let t_fl = self.batcher.as_ref().and_then(|b| b.flush_at);
            if t_arr.is_none() && t_ev.is_none() && t_fl.is_none() {
                if !self.parked.is_empty() {
                    return Err(format!(
                        "{}: generation loop stalled with {} parked \
                         requests",
                        self.sc.name,
                        self.parked.len()
                    ));
                }
                break;
            }
            if let Some(tf) = t_fl {
                if t_arr.map_or(true, |ta| tf < ta)
                    && t_ev.map_or(true, |te| tf < te)
                {
                    self.core.advance_to(tf);
                    self.flush(tf);
                    continue;
                }
            }
            match (t_arr, t_ev) {
                (Some(ta), te) if te.map_or(true, |te| ta <= te) => {
                    self.core.advance_to(ta);
                    while let Some((t, src)) = self.arrivals.peek() {
                        if t > ta {
                            break;
                        }
                        self.arrivals.pop();
                        self.arrival(src, t);
                    }
                    self.core.sample_queue_depth();
                }
                (_, Some(_)) => {
                    done_buf.clear();
                    self.core
                        .step(|id, _src, _arr, now| done_buf.push((id, now)));
                    for k in 0..done_buf.len() {
                        let (id, now) = done_buf[k];
                        if id >= BATCH_ID_BASE {
                            let members = self
                                .batches
                                .remove(&id)
                                .expect("unknown batch completion");
                            for g in members {
                                self.on_phase_done(g, now);
                            }
                        } else {
                            self.on_phase_done((id - 1) as usize, now);
                        }
                    }
                }
                _ => unreachable!(
                    "gen loop: impossible arrival/event state"
                ),
            }
        }
        Ok(())
    }

    fn into_report(mut self, gpu: &GpuSpec, opts: &GenOpts)
                   -> (GenReport, Option<Trace>) {
        let trace = self.core.take_trace();
        let max_normal_queue = self.core.max_normal_queue();
        let (span_us, metrics) = self.core.finish();
        let report = GenReport {
            scenario: self.sc.name.clone(),
            kind: "policy".into(),
            platform: gpu.name.clone(),
            scheduler: opts.scheduler.clone(),
            policy: opts.policy,
            seed: self.seed,
            duration_us: self.sc.duration_us,
            batch_window_us: opts.batch_window_us.unwrap_or(0.0),
            kv_budget_bytes: self.sc.kv_budget_bytes,
            kv_peak_bytes: self.kv_peak,
            span_us,
            events: metrics.events,
            tokens: self.tokens,
            drawn_tokens: self.drawn_tokens,
            evictions: self.evictions,
            preempted_steps: self.preempted_steps,
            recompute_tokens: self.recompute_tokens,
            evicted_prefix_tokens: self.evicted_prefix_tokens,
            ttft_violations: self.ttft_violations,
            max_normal_queue,
            critical_at_risk: self.ctrl.critical_at_risk(),
            tenants: std::mem::take(&mut self.tenants),
        };
        (report, trace)
    }
}

fn run_gen_inner(gpu: &GpuSpec, sc: &GenScenarioSpec, opts: &GenOpts,
                 trace: bool) -> Result<(GenReport, Option<Trace>), String> {
    let mut sim = GenSim::new(gpu, sc, opts, trace)?;
    sim.run()?;
    Ok(sim.into_report(gpu, opts))
}

/// Serve one generation scenario through one configuration until the
/// system drains. Deterministic for a given (scenario, seed, policy,
/// scheduler, batch window): the loop advances in simulated time only
/// and no host timing enters the report.
pub fn run_gen(gpu: &GpuSpec, sc: &GenScenarioSpec, opts: &GenOpts)
               -> Result<GenReport, String> {
    run_gen_inner(gpu, sc, opts, false).map(|(r, _)| r)
}

/// [`run_gen`] with the engine trace recorder attached — the
/// golden-trace path.
pub fn run_gen_traced(gpu: &GpuSpec, sc: &GenScenarioSpec, opts: &GenOpts)
                      -> Result<(GenReport, Trace), String> {
    let (report, trace) = run_gen_inner(gpu, sc, opts, true)?;
    Ok((report, trace.ok_or("trace recorder returned nothing")?))
}

/// A generation grid: scenarios × admission policies, plus the
/// solo-criticals / sequential / continuous-batching comparison rows
/// per scenario (the `BENCH_gen.json` document).
#[derive(Debug, Clone)]
pub struct GenGridReport {
    /// GPU preset name.
    pub platform: String,
    /// Coordinator the policy cells served through.
    pub scheduler: String,
    /// Arrival-generation window per cell (us).
    pub duration_us: f64,
    /// Policy names, in run order.
    pub policies: Vec<String>,
    /// Scenario names, in run order.
    pub scenarios: Vec<String>,
    /// Cells in deterministic grid order: scenario-major — each
    /// scenario's policy cells (kind `policy`), then its `solo`,
    /// `sequential`, and `batched` comparison rows.
    pub cells: Vec<GenReport>,
}

impl GenGridReport {
    /// The first cell matching (scenario, kind[, policy]), if any.
    pub fn cell(&self, scenario: &str, kind: &str,
                policy: Option<AdmissionPolicy>) -> Option<&GenReport> {
        self.cells.iter().find(|c| {
            c.scenario == scenario
                && c.kind == kind
                && policy.map_or(true, |p| c.policy == p)
        })
    }

    /// Per-scenario comparison rows derived from the cells: critical
    /// TTFT under deadline-feasible admission vs the solo run, and
    /// tokens/sec + inter-token p99 across miriam / sequential /
    /// continuous batching.
    fn comparisons(&self) -> Vec<Json> {
        let num = Json::Num;
        self.scenarios
            .iter()
            .filter_map(|sc| {
                // Miriam reference row: the Open-policy cell, falling
                // back to the first policy cell when the run's policy
                // list omits Open.
                let open = self
                    .cell(sc, "policy", Some(AdmissionPolicy::Open))
                    .or_else(|| self.cell(sc, "policy", None))?;
                let df = self.cell(sc, "policy",
                                   Some(AdmissionPolicy::DeadlineFeasible));
                let solo = self.cell(&format!("{sc}-solo"), "solo", None)?;
                let seq = self.cell(sc, "sequential", None)?;
                let bat = self.cell(sc, "batched", None)?;
                let mixed_ttft = df.unwrap_or(open).crit_ttft_p99_us();
                let solo_ttft = solo.crit_ttft_p99_us();
                let mut m = BTreeMap::new();
                m.insert("scenario".into(), Json::Str(sc.clone()));
                m.insert("crit_ttft_p99_us".into(), num(mixed_ttft));
                m.insert("solo_crit_ttft_p99_us".into(), num(solo_ttft));
                m.insert("ttft_ratio".into(), num(mixed_ttft / solo_ttft));
                m.insert("miriam_tokens_per_sec".into(),
                         num(open.tokens_per_sec()));
                m.insert("sequential_tokens_per_sec".into(),
                         num(seq.tokens_per_sec()));
                m.insert("batched_tokens_per_sec".into(),
                         num(bat.tokens_per_sec()));
                m.insert("miriam_inter_token_p99_us".into(),
                         num(open.inter_token_quantile_us(0.99)));
                m.insert("batched_inter_token_p99_us".into(),
                         num(bat.inter_token_quantile_us(0.99)));
                Some(Json::Obj(m))
            })
            .collect()
    }

    /// The canonical `BENCH_gen.json` document: sorted keys, no
    /// whitespace, no host-timing fields — byte-deterministic per seed
    /// for any thread count (schema in EXPERIMENTS.md §Generation).
    pub fn to_json(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("bench".into(), Json::Str("gen".into()));
        obj.insert("platform".into(), Json::Str(self.platform.clone()));
        obj.insert("scheduler".into(), Json::Str(self.scheduler.clone()));
        obj.insert("duration_us".into(), Json::Num(self.duration_us));
        obj.insert(
            "policies".into(),
            Json::Arr(self.policies.iter().cloned().map(Json::Str).collect()),
        );
        obj.insert(
            "scenarios".into(),
            Json::Arr(self.scenarios.iter().cloned().map(Json::Str).collect()),
        );
        obj.insert(
            "cells".into(),
            Json::Arr(self.cells.iter().map(|c| c.to_json_value()).collect()),
        );
        obj.insert("comparisons".into(), Json::Arr(self.comparisons()));
        obj.insert("version".into(), Json::Num(1.0));
        Json::Obj(obj).to_canonical_string()
    }
}

/// Run the generation grid: for every scenario, every admission policy
/// (kind `policy`, `base` scheduler, no batching) plus the three
/// comparison rows — `solo` (criticals only, open admission),
/// `sequential` (the no-elasticity baseline scheduler), and `batched`
/// (continuous batching at [`GEN_BATCH_WINDOW_US`], or
/// `base.batch_window_us` when set). Cells are independent and
/// deterministic, so `threads > 1` changes wall-clock only — the
/// report is byte-identical for any thread count.
pub fn run_gen_grid(gpu: &GpuSpec, scenarios: &[GenScenarioSpec],
                    policies: &[AdmissionPolicy], base: &GenOpts,
                    threads: usize) -> Result<GenGridReport, String> {
    if scenarios.is_empty() {
        return Err("gen grid: no scenarios".into());
    }
    if policies.is_empty() {
        return Err("gen grid: no policies".into());
    }
    let window = base.batch_window_us.unwrap_or(GEN_BATCH_WINDOW_US);
    let mut jobs: Vec<(GenScenarioSpec, GenOpts, &'static str)> = Vec::new();
    for sc in scenarios {
        for &policy in policies {
            let opts = GenOpts { policy, batch_window_us: None,
                                 ..base.clone() };
            jobs.push((sc.clone(), opts, "policy"));
        }
        jobs.push((
            sc.solo_criticals(),
            GenOpts { policy: AdmissionPolicy::Open, batch_window_us: None,
                      ..base.clone() },
            "solo",
        ));
        jobs.push((
            sc.clone(),
            GenOpts {
                scheduler: "sequential".into(),
                policy: AdmissionPolicy::Open,
                batch_window_us: None,
                ..base.clone()
            },
            "sequential",
        ));
        jobs.push((
            sc.clone(),
            GenOpts {
                policy: AdmissionPolicy::Open,
                batch_window_us: Some(window),
                ..base.clone()
            },
            "batched",
        ));
    }

    let slots: Vec<Mutex<Option<Result<GenReport, String>>>> =
        jobs.iter().map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let workers = threads.max(1).min(jobs.len());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let (sc, opts, kind) = &jobs[i];
                let res = run_gen(gpu, sc, opts).map(|mut r| {
                    r.kind = (*kind).into();
                    r
                });
                *slots[i].lock().expect("gen grid slot poisoned") = Some(res);
            });
        }
    });

    let mut cells = Vec::with_capacity(jobs.len());
    for slot in slots {
        let cell = slot
            .into_inner()
            .expect("gen grid slot poisoned")
            .ok_or("gen grid: job never ran")??;
        cells.push(cell);
    }
    Ok(GenGridReport {
        platform: gpu.name.clone(),
        scheduler: base.scheduler.clone(),
        duration_us: scenarios[0].duration_us,
        policies: policies.iter().map(|p| p.name().to_string()).collect(),
        scenarios: scenarios.iter().map(|s| s.name.clone()).collect(),
        cells,
    })
}

/// Record the pinned generation golden cells
/// ([`crate::workloads::generation::GEN_GOLDEN_CELLS`]) as canonical
/// traces under `dir` (`rust/tests/golden/gen/`), at the same pinned
/// duration/platform as the main golden set. Returns (path, events)
/// per cell.
pub fn record_gen_golden_traces(
    dir: &std::path::Path,
) -> std::io::Result<Vec<(std::path::PathBuf, usize)>> {
    use crate::workloads::generation::GEN_GOLDEN_CELLS;
    use crate::workloads::scenario::{
        golden_file_name, GOLDEN_DURATION_US, GOLDEN_PLATFORM,
    };
    std::fs::create_dir_all(dir)?;
    let spec = GpuSpec::by_name(GOLDEN_PLATFORM)
        .expect("golden platform preset exists");
    let mut out = Vec::new();
    for (sc_name, sched) in GEN_GOLDEN_CELLS {
        let sc = crate::workloads::generation::gen_by_name(
            sc_name,
            GOLDEN_DURATION_US,
        )
        .expect("gen golden cell names a known scenario");
        let opts = GenOpts { scheduler: sched.into(), ..GenOpts::default() };
        let (_, trace) = run_gen_traced(&spec, &sc, &opts)
            .map_err(std::io::Error::other)?;
        let path = dir.join(golden_file_name(sc_name, sched));
        std::fs::write(&path, trace.to_canonical_json())?;
        out.push((path, trace.len()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::generation::{gen_diff, gen_family};

    fn gpu() -> GpuSpec {
        GpuSpec::rtx2060()
    }

    #[test]
    fn gen_run_conserves_tokens_and_requests() {
        let sc = &gen_family(20_000.0)[0];
        let r = run_gen(&gpu(), sc, &GenOpts::default()).unwrap();
        assert!(r.offered() > 0, "no arrivals in window");
        assert_eq!(r.offered(), r.admitted() + r.shed());
        assert_eq!(r.admitted(), r.served(), "admitted requests must drain");
        assert_eq!(r.tokens, r.drawn_tokens, "token conservation");
        assert_eq!(r.ttft_violations, 0);
        assert_eq!(r.critical_evictions(), 0);
        assert!(r.kv_peak_bytes <= r.kv_budget_bytes + 1e-6);
        // Every served request produced a TTFT sample and a latency.
        for t in &r.tenants {
            assert_eq!(t.ttft_us.len() as u64, t.served, "{}", t.label);
            assert_eq!(t.latencies_us.len() as u64, t.served, "{}", t.label);
        }
    }

    #[test]
    fn gen_pressure_evicts_normals_and_recompute_matches_prefix() {
        let sc = &gen_family(40_000.0)[1]; // gen-pressure
        let r = run_gen(&gpu(), sc, &GenOpts::default()).unwrap();
        assert!(r.evictions > 0, "pressure scenario produced no evictions");
        assert_eq!(r.recompute_tokens, r.evicted_prefix_tokens,
                   "recompute must re-issue exactly the evicted prefix");
        assert_eq!(r.critical_evictions(), 0);
        assert_eq!(r.tokens, r.drawn_tokens);
    }

    #[test]
    fn gen_run_is_deterministic_per_seed() {
        let sc = &gen_family(15_000.0)[0];
        let a = run_gen(&gpu(), sc, &GenOpts::default()).unwrap();
        let b = run_gen(&gpu(), sc, &GenOpts::default()).unwrap();
        assert_eq!(a.to_json_value().to_canonical_string(),
                   b.to_json_value().to_canonical_string());
        let c = run_gen(&gpu(), sc,
                        &GenOpts { seed: Some(99), ..GenOpts::default() })
            .unwrap();
        assert_eq!(c.seed, 99);
    }

    #[test]
    fn batched_mode_batches_and_still_conserves() {
        let sc = &gen_family(20_000.0)[0];
        let opts = GenOpts {
            batch_window_us: Some(GEN_BATCH_WINDOW_US),
            ..GenOpts::default()
        };
        let r = run_gen(&gpu(), sc, &opts).unwrap();
        assert_eq!(r.tokens, r.drawn_tokens);
        assert_eq!(r.admitted(), r.served());
        assert_eq!(r.batch_window_us, GEN_BATCH_WINDOW_US);
    }

    #[test]
    fn grid_runs_all_kinds_and_is_thread_invariant() {
        let scs = vec![gen_family(10_000.0)[0].clone()];
        let pols = [AdmissionPolicy::Open, AdmissionPolicy::DeadlineFeasible];
        let g1 = run_gen_grid(&gpu(), &scs, &pols, &GenOpts::default(), 1)
            .unwrap();
        let g4 = run_gen_grid(&gpu(), &scs, &pols, &GenOpts::default(), 4)
            .unwrap();
        assert_eq!(g1.to_json(), g4.to_json());
        assert_eq!(g1.cells.len(), pols.len() + 3);
        for kind in ["policy", "solo", "sequential", "batched"] {
            assert!(g1.cells.iter().any(|c| c.kind == kind), "{kind}");
        }
        assert!(g1.to_json().contains("\"comparisons\""));
    }

    #[test]
    fn diff_scenario_emits_exactly_one_token_per_request() {
        let sc = gen_diff(10_000.0);
        let r = run_gen(&gpu(), &sc, &GenOpts::default()).unwrap();
        assert_eq!(r.tokens, r.served());
        assert_eq!(r.evictions, 0);
        for t in &r.tenants {
            assert!(t.inter_token_us.is_empty(), "{}", t.label);
            // TTFT == end-to-end for 1-token requests.
            for (a, b) in t.ttft_us.iter().zip(&t.latencies_us) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn rejects_invalid_config() {
        let sc = &gen_family(10_000.0)[0];
        let bad = GenOpts {
            batch_window_us: Some(0.0),
            ..GenOpts::default()
        };
        assert!(run_gen(&gpu(), sc, &bad).is_err());
        let bad_sched = GenOpts {
            scheduler: "nope".into(),
            ..GenOpts::default()
        };
        assert!(run_gen(&gpu(), sc, &bad_sched).is_err());
        let mut bad_sc = sc.clone();
        bad_sc.kv_budget_bytes = 10.0;
        assert!(run_gen(&gpu(), &bad_sc, &GenOpts::default()).is_err());
    }
}
