//! The online admission-controlled serving pipeline (ISSUE 4 tentpole).
//!
//! Where [`crate::coordinator::driver`] runs a *closed* evaluation batch
//! (every arrival admitted, stats summed per class), this module runs the
//! deployment-shaped loop the ROADMAP asks for: a long-lived
//! simulated-time service that pulls open-loop arrivals from any
//! [`ScenarioSpec`], passes each one through an
//! [`AdmissionController`](crate::coordinator::admission), and feeds the
//! admitted requests into the live coordinator (Miriam by default — whose
//! submissions flow through `Engine::submit_interned`). Per-tenant SLO
//! outcomes are accounted the whole way:
//!
//! * **offered** — every arrival seen (including closed-loop retries);
//! * **admitted / shed** — the admission decision split
//!   (`offered == admitted + shed` always; critical is never shed);
//! * **served** — admitted requests that completed, with per-tenant
//!   p50/p99/mean latency and deadline misses.
//!
//! [`run_serve`] executes one (scenario, policy) cell; [`run_serve_grid`]
//! sweeps scenarios × policies and serializes the whole comparison as
//! canonical JSON (`BENCH_serve.json`, schema in EXPERIMENTS.md §Serve,
//! mirroring `BENCH_sweep.json`). Reports carry **no host-timing
//! fields**, so a run is byte-deterministic per seed —
//! `rust/tests/serve_determinism.rs` pins repeat-run equality and that
//! the `none` policy reproduces the batch driver's trajectory exactly.
//!
//! ```
//! use miriam::gpu::spec::GpuSpec;
//! use miriam::server::online::{run_serve, ServeOpts};
//! use miriam::workloads::scenario;
//!
//! let sc = scenario::by_name("duo-burst", 5_000.0).unwrap();
//! let report =
//!     run_serve(&GpuSpec::rtx2060(), &sc, &ServeOpts::default()).unwrap();
//! assert_eq!(report.offered(), report.admitted() + report.shed());
//! assert_eq!(report.shed_critical(), 0); // critical is never shed
//! ```

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use crate::coordinator::admission::{
    AdmissionConfig, AdmissionController, AdmissionPolicy, Decision,
};
use crate::coordinator::driver::initial_arrivals;
use crate::coordinator::scheduler::{Req, Scheduler};
use crate::coordinator::scheduler_for;
use crate::coordinator::stats::{mean, merged_quantile, sorted_quantile};
use crate::gpu::contention::ContentionParams;
use crate::gpu::engine::{Completion, Engine};
use crate::gpu::kernel::Criticality;
use crate::gpu::metrics::SimMetrics;
use crate::gpu::spec::GpuSpec;
use crate::runtime::json::Json;
use crate::workloads::mdtb::Workload;
use crate::workloads::models::ModelRef;
use crate::workloads::rng::Rng;
use crate::workloads::scenario::ScenarioSpec;

/// Reject an [`AdmissionConfig`] whose shed backoff would livelock the
/// simulated-time loop (a zero backoff re-offers a shed closed-loop
/// request at the same instant, forever). Shared precondition of the
/// single-device loop ([`run_serve`]) and the fleet loop
/// (`crate::fleet::run_fleet`).
pub(crate) fn validate_admission(cfg: &AdmissionConfig) -> Result<(), String> {
    if !(cfg.shed_backoff_us > 0.0) || !cfg.shed_backoff_us.is_finite() {
        return Err("shed_backoff_us must be positive and finite \
                    (a zero backoff re-offers a shed closed-loop request \
                    at the same instant, forever)"
            .into());
    }
    Ok(())
}

/// The per-device serving core: one engine + scheduler + open-request
/// table, with the request-construction and completion-drain mechanics of
/// the serving loop factored out so the single-device path ([`run_serve`])
/// and the fleet loop (`crate::fleet::run_fleet`) walk the *same* code —
/// the ISSUE 5 differential contract (a 1-device fleet reproduces
/// `serve-sim` bitwise) holds structurally, not by accident.
///
/// The core owns everything local to a device; arrivals, admission,
/// tenant accounting, and closed-loop regeneration stay with the caller,
/// which drives the core through `advance_to`/`submit`/`step`.
pub(crate) struct DeviceCore {
    eng: Engine,
    sched: Box<dyn Scheduler>,
    /// Interned kernel-name ids per source (valid for `eng` only).
    name_ids: Vec<Arc<Vec<u32>>>,
    /// req id -> (arrival time, source) for requests in flight here.
    open: HashMap<u64, (f64, usize)>,
    completions: Vec<Completion>,
    finished: Vec<u64>,
    max_normal_queue: usize,
}

impl DeviceCore {
    /// Build a core for `wl` on `gpu` under the named scheduler, with the
    /// per-source kernel names interned once up front (the ISSUE 3
    /// zero-clone fast path, same as the batch driver).
    pub(crate) fn new(gpu: &GpuSpec, wl: &Workload, scheduler: &str)
                      -> Result<Self, String> {
        Self::new_traced(gpu, wl, scheduler, false)
    }

    /// [`DeviceCore::new`] with an optional engine trace recorder
    /// attached — the generation golden-trace recorder
    /// (`crate::server::gen`) records through the same core the serving
    /// loops run, so goldens pin the served trajectory, not a replica.
    pub(crate) fn new_traced(gpu: &GpuSpec, wl: &Workload, scheduler: &str,
                             trace: bool) -> Result<Self, String> {
        let mut sched = scheduler_for(scheduler, wl)
            .ok_or_else(|| format!("unknown scheduler {scheduler}"))?;
        let mut eng = Engine::new(gpu.clone());
        if trace {
            eng = eng.with_trace();
        }
        sched.init(&mut eng);
        // Intern each distinct model once, keyed by the `Arc` pointer: a
        // 100k-tenant scale workload shares a handful of model Arcs
        // across all sources, so this stays O(models), not O(tenants).
        // Distinct Arcs to equal models just miss the cache — correct,
        // only slower — and the pre-scale paths (one Arc per source)
        // behave exactly as before.
        let mut interned: HashMap<usize, Arc<Vec<u32>>> = HashMap::new();
        let name_ids: Vec<Arc<Vec<u32>>> = wl
            .sources
            .iter()
            .map(|s| {
                interned
                    .entry(Arc::as_ptr(&s.model) as usize)
                    .or_insert_with(|| {
                        Arc::new(
                            s.model
                                .intern_kernels(|n| eng.intern_name(n)),
                        )
                    })
                    .clone()
            })
            .collect();
        Ok(DeviceCore {
            eng,
            sched,
            name_ids,
            open: HashMap::new(),
            completions: Vec::new(),
            finished: Vec::new(),
            max_normal_queue: 0,
        })
    }

    /// The device's GPU spec.
    pub(crate) fn spec(&self) -> &GpuSpec {
        &self.eng.spec
    }

    /// Take the recorded engine trace, if tracing was enabled at
    /// construction (drains the recorder; call once, after the run).
    pub(crate) fn take_trace(&mut self) -> Option<crate::gpu::trace::Trace> {
        self.eng.take_trace()
    }

    /// The pre-interned kernel-name ids of source `src` (cheap `Arc`
    /// clone). The generation layer seeds its phase-graph cache with
    /// this so a request's first prefill reuses the exact ids
    /// [`DeviceCore::submit`] would use.
    pub(crate) fn source_name_ids(&self, src: usize) -> Arc<Vec<u32>> {
        self.name_ids[src].clone()
    }

    /// Intern an out-of-workload model's kernel names (decode-step and
    /// recompute graphs, which don't exist in the base workload). Done
    /// once per distinct graph at cache fill; per-step resubmission then
    /// stays on the interned fast path.
    pub(crate) fn intern_model(
        &mut self,
        model: &crate::workloads::models::ModelDesc,
    ) -> Arc<Vec<u32>> {
        let eng = &mut self.eng;
        Arc::new(model.intern_kernels(|n| eng.intern_name(n)))
    }

    /// The device's contention parameters.
    pub(crate) fn params(&self) -> &ContentionParams {
        &self.eng.params
    }

    /// Time of the device's next internal event, if any.
    pub(crate) fn next_event_time(&mut self) -> Option<f64> {
        self.eng.next_event_time()
    }

    /// Advance the device's simulated clock (must not skip an event).
    pub(crate) fn advance_to(&mut self, t: f64) {
        self.eng.advance_to(t);
    }

    /// Hand the admitted arrival from `src` at time `t` to the scheduler
    /// as request `id` (ids are assigned by the caller so they stay
    /// unique across a whole fleet).
    pub(crate) fn submit(&mut self, wl: &Workload, src: usize, t: f64,
                         id: u64) {
        let s = &wl.sources[src];
        let req = Req {
            id,
            source: src,
            model: s.model.clone(),
            name_ids: self.name_ids[src].clone(),
            criticality: s.criticality,
            arrival_us: t,
        };
        self.open.insert(id, (t, src));
        self.sched.on_request(req, &mut self.eng);
    }

    /// [`DeviceCore::submit`] for an explicit (model, interned ids)
    /// pair — the generation layer's per-phase entry point (decode
    /// steps, recompute prefills, batched decode groups), where the
    /// graph changes per step and so cannot come from the per-source
    /// table. Same request construction, same open-table bookkeeping,
    /// zero allocation (both handles are `Arc` clones).
    pub(crate) fn submit_model(&mut self, model: &ModelRef,
                               name_ids: &Arc<Vec<u32>>, src: usize,
                               criticality: Criticality, t: f64, id: u64) {
        let req = Req {
            id,
            source: src,
            model: model.clone(),
            name_ids: name_ids.clone(),
            criticality,
            arrival_us: t,
        };
        self.open.insert(id, (t, src));
        self.sched.on_request(req, &mut self.eng);
    }

    /// Sample the scheduler's best-effort queue depth into the running
    /// per-device maximum (called after each arrival batch).
    pub(crate) fn sample_queue_depth(&mut self) {
        if let Some(q) = self.sched.pending_normal() {
            self.max_normal_queue = self.max_normal_queue.max(q);
        }
    }

    /// Peak best-effort queue depth observed so far.
    pub(crate) fn max_normal_queue(&self) -> usize {
        self.max_normal_queue
    }

    /// Requests currently in flight on this device.
    pub(crate) fn open_count(&self) -> usize {
        self.open.len()
    }

    /// Take every in-flight request off the device as
    /// `(id, arrival_us, source)` rows, **sorted by id** so the caller's
    /// re-routing order never depends on `HashMap` iteration order —
    /// the chaos layer's determinism hinges on this (ISSUE 6). The
    /// caller retires or rebuilds the core afterwards; any kernels the
    /// dead device had queued die with its engine.
    pub(crate) fn drain_open(&mut self) -> Vec<(u64, f64, usize)> {
        let mut rows: Vec<(u64, f64, usize)> = self
            .open
            .drain()
            .map(|(id, (arr, src))| (id, arr, src))
            .collect();
        rows.sort_unstable_by_key(|&(id, _, _)| id);
        rows
    }

    /// True while request `id` is still in flight on this device.
    pub(crate) fn has_open(&self, id: u64) -> bool {
        self.open.contains_key(&id)
    }

    /// Best-effort cancellation of in-flight request `id` (ISSUE 8
    /// recovery layer). The open entry is removed **only** when the
    /// scheduler accepted the cancellation — i.e. it removed every
    /// queued launch and will never report the request finished.
    /// Otherwise the request stays open and runs to completion (the
    /// baselines' default `Scheduler::cancel` declines; dispatched work
    /// cannot be recalled). Returns the `(arrival_us, source)` row on
    /// success.
    pub(crate) fn cancel(&mut self, id: u64) -> Option<(f64, usize)> {
        if !self.open.contains_key(&id) {
            return None;
        }
        if !self.sched.cancel(id, &mut self.eng) {
            return None;
        }
        self.open.remove(&id)
    }

    /// Toggle the scheduler's brownout mode (no-op for schedulers
    /// without the lever).
    pub(crate) fn set_brownout(&mut self, on: bool) {
        self.sched.set_brownout(on);
    }

    /// Process the device's next event: step the engine once and drain
    /// the resulting completions through the scheduler. `served` fires
    /// once per finished request — in completion order, *inside* the
    /// drain, exactly where the pre-fleet loop did its accounting — as
    /// `(id, source, arrival_us, now_us)`.
    pub(crate) fn step(&mut self,
                       mut served: impl FnMut(u64, usize, f64, f64)) {
        self.eng.step_into(&mut self.completions);
        for c in &self.completions {
            self.finished.clear();
            self.sched.on_completion(c, &mut self.eng, &mut self.finished);
            for &fid in &self.finished {
                let (arr, src) = self
                    .open
                    .remove(&fid)
                    .expect("scheduler finished unknown request");
                served(fid, src, arr, self.eng.now_us());
            }
        }
    }

    /// Tear the device down: (simulated span, engine metrics).
    pub(crate) fn finish(self) -> (f64, SimMetrics) {
        let span = self.eng.now_us();
        (span, self.eng.into_metrics())
    }
}

/// Configuration of one serving run.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Coordinator to serve through (any `scheduler_for` name; the
    /// deployment default is `miriam`).
    pub scheduler: String,
    /// Admission policy applied to best-effort arrivals.
    pub policy: AdmissionPolicy,
    /// Policy tunables (buckets, burst guard, shed backoff).
    pub admission: AdmissionConfig,
    /// Override the scenario's pinned arrival seed (`None` keeps it).
    pub seed: Option<u64>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            scheduler: "miriam".into(),
            policy: AdmissionPolicy::Open,
            admission: AdmissionConfig::default(),
            seed: None,
        }
    }
}

/// SLO outcome of one tenant over a serving run.
#[derive(Debug, Clone)]
pub struct TenantOutcome {
    /// Source index in the scenario.
    pub source: usize,
    /// Stable label (`ScenarioSpec::tenant_label`).
    pub label: String,
    /// Model name served for this tenant.
    pub model: String,
    /// Task class.
    pub criticality: Criticality,
    /// Arrivals seen (including closed-loop shed retries).
    pub offered: u64,
    /// Arrivals admitted into the coordinator.
    pub admitted: u64,
    /// Arrivals shed by the admission policy.
    pub shed: u64,
    /// Admitted requests that completed within the run.
    pub served: u64,
    /// Served requests that exceeded the tenant's deadline.
    pub deadline_misses: u64,
    /// Times one of this tenant's admitted requests was re-routed off a
    /// dead or draining device (chaos layer; 0 without chaos).
    pub requeues: u64,
    /// Admitted requests lost to a terminal outage — the whole fleet
    /// was dark when the request needed a device and never recovered
    /// (0 whenever ≥ 1 device stays live).
    pub lost: u64,
    /// Launch retries performed for this tenant by the recovery layer
    /// after transient failures or corrupted completions (fault layer;
    /// 0 without faults).
    pub retries: u64,
    /// Hedged duplicate launches placed for this tenant's critical
    /// requests past the deadline-risk watermark (0 without faults).
    pub hedges: u64,
    /// Hedged requests whose *hedge* copy reported first — each counted
    /// exactly once (0 without faults).
    pub hedge_wins: u64,
    /// Best-effort requests cancelled by the recovery layer (deadline
    /// doomed or retry budget exhausted). Never applied to critical
    /// tenants; conservation extends to
    /// `admitted == served + lost + cancelled` (0 without faults).
    pub cancelled: u64,
    /// End-to-end latency (us) of each served request.
    pub latencies_us: Vec<f64>,
    /// Output tokens emitted and kept for this tenant (generation
    /// workloads; 0 for fixed-chain tenants).
    pub tokens: u64,
    /// Served generation requests whose first token missed the tenant's
    /// TTFT deadline (0 without one).
    pub ttft_misses: u64,
    /// Inter-token gaps that exceeded the tenant's per-token budget
    /// (0 without one).
    pub token_misses: u64,
    /// Times one of this tenant's resident requests was evicted from
    /// the KV cache under memory pressure (generation; never > 0 for
    /// critical tenants).
    pub evictions: u64,
    /// In-flight steps whose output was discarded because the request
    /// was evicted mid-step (each re-runs after recompute).
    pub preempted_steps: u64,
    /// Time-to-first-token (us) of each served generation request.
    pub ttft_us: Vec<f64>,
    /// Inter-token gap (us) of every kept decode token.
    pub inter_token_us: Vec<f64>,
}

impl TenantOutcome {
    /// Median served latency (us; NaN when nothing was served).
    pub fn p50_us(&self) -> f64 {
        sorted_quantile(&self.latencies_us, 0.5)
    }

    /// 99th-percentile served latency (us; NaN when nothing was served).
    pub fn p99_us(&self) -> f64 {
        sorted_quantile(&self.latencies_us, 0.99)
    }

    /// Mean served latency (us; NaN when nothing was served).
    pub fn mean_us(&self) -> f64 {
        mean(&self.latencies_us)
    }

    /// Median time-to-first-token (us; NaN when nothing was served).
    pub fn ttft_p50_us(&self) -> f64 {
        sorted_quantile(&self.ttft_us, 0.5)
    }

    /// 99th-percentile time-to-first-token (us; NaN when empty).
    pub fn ttft_p99_us(&self) -> f64 {
        sorted_quantile(&self.ttft_us, 0.99)
    }

    /// 99th-percentile inter-token gap (us; NaN when empty).
    pub fn inter_token_p99_us(&self) -> f64 {
        sorted_quantile(&self.inter_token_us, 0.99)
    }
}

/// Outcome of one (scenario, policy) serving cell.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Scenario name.
    pub scenario: String,
    /// GPU preset name.
    pub platform: String,
    /// Coordinator the run served through.
    pub scheduler: String,
    /// Admission policy applied.
    pub policy: AdmissionPolicy,
    /// Arrival seed the run actually used.
    pub seed: u64,
    /// Arrival-generation window (us).
    pub duration_us: f64,
    /// Per-tenant outcomes, in source order.
    pub tenants: Vec<TenantOutcome>,
    /// Simulated span until the system drained (us).
    pub span_us: f64,
    /// Simulator events processed.
    pub events: u64,
    /// Peak best-effort queue depth inside the coordinator (0 when the
    /// scheduler does not expose one).
    pub max_normal_queue: usize,
    /// Critical arrivals whose deadline was infeasible by the solo
    /// envelope (admitted regardless; see `AdmissionController`).
    pub critical_at_risk: u64,
}

impl ServeReport {
    /// Total arrivals seen.
    pub fn offered(&self) -> u64 {
        self.tenants.iter().map(|t| t.offered).sum()
    }

    /// Total arrivals admitted.
    pub fn admitted(&self) -> u64 {
        self.tenants.iter().map(|t| t.admitted).sum()
    }

    /// Total arrivals shed.
    pub fn shed(&self) -> u64 {
        self.tenants.iter().map(|t| t.shed).sum()
    }

    /// Total requests served to completion.
    pub fn served(&self) -> u64 {
        self.tenants.iter().map(|t| t.served).sum()
    }

    /// Shed count over critical tenants — zero by the admission
    /// invariant, recorded so tests and reports can assert it.
    pub fn shed_critical(&self) -> u64 {
        self.class_sum(Criticality::Critical, |t| t.shed)
    }

    /// Deadline misses over critical tenants.
    pub fn deadline_misses_critical(&self) -> u64 {
        self.class_sum(Criticality::Critical, |t| t.deadline_misses)
    }

    /// Deadline misses over best-effort tenants.
    pub fn deadline_misses_normal(&self) -> u64 {
        self.class_sum(Criticality::Normal, |t| t.deadline_misses)
    }

    fn class_sum(&self, c: Criticality, f: impl Fn(&TenantOutcome) -> u64)
                 -> u64 {
        self.tenants
            .iter()
            .filter(|t| t.criticality == c)
            .map(f)
            .sum()
    }

    fn class_quantile(&self, c: Criticality, q: f64) -> f64 {
        merged_quantile(
            self.tenants
                .iter()
                .filter(|t| t.criticality == c)
                .map(|t| t.latencies_us.as_slice()),
            q,
        )
    }

    /// Critical-class latency quantile over all critical tenants.
    pub fn crit_quantile_us(&self, q: f64) -> f64 {
        self.class_quantile(Criticality::Critical, q)
    }

    /// Critical-class p99 latency (us).
    pub fn crit_p99_us(&self) -> f64 {
        self.crit_quantile_us(0.99)
    }

    /// Best-effort-class latency quantile.
    pub fn normal_quantile_us(&self, q: f64) -> f64 {
        self.class_quantile(Criticality::Normal, q)
    }

    /// Served best-effort requests per second of simulated span — the
    /// throughput each policy trades against critical latency.
    pub fn normal_throughput_rps(&self) -> f64 {
        if self.span_us <= 0.0 {
            return 0.0;
        }
        self.class_sum(Criticality::Normal, |t| t.served) as f64
            / (self.span_us / 1e6)
    }

    /// Served requests (both classes) per second of simulated span.
    pub fn throughput_rps(&self) -> f64 {
        if self.span_us <= 0.0 {
            return 0.0;
        }
        self.served() as f64 / (self.span_us / 1e6)
    }

    /// This cell as a canonical-JSON value (one `cells[]` row of
    /// `BENCH_serve.json`; non-finite quantiles serialize as `null`).
    pub fn to_json_value(&self) -> Json {
        let num = Json::Num;
        let mut m = BTreeMap::new();
        m.insert("scenario".into(), Json::Str(self.scenario.clone()));
        m.insert("policy".into(), Json::Str(self.policy.name().into()));
        m.insert("scheduler".into(), Json::Str(self.scheduler.clone()));
        m.insert("seed".into(), num(self.seed as f64));
        m.insert("duration_us".into(), num(self.duration_us));
        m.insert("span_us".into(), num(self.span_us));
        m.insert("events".into(), num(self.events as f64));
        m.insert("offered".into(), num(self.offered() as f64));
        m.insert("admitted".into(), num(self.admitted() as f64));
        m.insert("shed".into(), num(self.shed() as f64));
        m.insert("served".into(), num(self.served() as f64));
        m.insert("shed_critical".into(), num(self.shed_critical() as f64));
        m.insert("crit_p50_us".into(), num(self.crit_quantile_us(0.5)));
        m.insert("crit_p99_us".into(), num(self.crit_p99_us()));
        m.insert("normal_p50_us".into(), num(self.normal_quantile_us(0.5)));
        m.insert("normal_throughput_rps".into(),
                 num(self.normal_throughput_rps()));
        m.insert("throughput_rps".into(), num(self.throughput_rps()));
        m.insert("deadline_misses_critical".into(),
                 num(self.deadline_misses_critical() as f64));
        m.insert("deadline_misses_normal".into(),
                 num(self.deadline_misses_normal() as f64));
        m.insert("max_normal_queue".into(),
                 num(self.max_normal_queue as f64));
        m.insert("critical_at_risk".into(),
                 num(self.critical_at_risk as f64));
        m.insert(
            "tenants".into(),
            Json::Arr(self.tenants.iter().map(tenant_json).collect()),
        );
        Json::Obj(m)
    }
}

/// One per-tenant row of a serving report as canonical JSON — shared by
/// `BENCH_serve.json` and `BENCH_fleet.json` so the two documents can
/// never drift on what a tenant row contains.
pub(crate) fn tenant_json(t: &TenantOutcome) -> Json {
    let num = Json::Num;
    let mut tm = BTreeMap::new();
    tm.insert("label".into(), Json::Str(t.label.clone()));
    tm.insert("model".into(), Json::Str(t.model.clone()));
    tm.insert(
        "criticality".into(),
        Json::Str(
            match t.criticality {
                Criticality::Critical => "critical",
                Criticality::Normal => "normal",
            }
            .into(),
        ),
    );
    tm.insert("offered".into(), num(t.offered as f64));
    tm.insert("admitted".into(), num(t.admitted as f64));
    tm.insert("shed".into(), num(t.shed as f64));
    tm.insert("served".into(), num(t.served as f64));
    tm.insert("deadline_misses".into(), num(t.deadline_misses as f64));
    tm.insert("p50_us".into(), num(t.p50_us()));
    tm.insert("p99_us".into(), num(t.p99_us()));
    tm.insert("mean_us".into(), num(t.mean_us()));
    Json::Obj(tm)
}

/// The resilience variant of [`tenant_json`]: the same row plus the
/// chaos-only counters. Kept separate so `BENCH_serve.json` and
/// zero-chaos `BENCH_fleet.json` documents stay byte-identical to their
/// pre-chaos forms (ISSUE 6 determinism contract).
pub(crate) fn tenant_json_resilience(t: &TenantOutcome) -> Json {
    match tenant_json(t) {
        Json::Obj(mut tm) => {
            tm.insert("requeues".into(), Json::Num(t.requeues as f64));
            tm.insert("lost".into(), Json::Num(t.lost as f64));
            Json::Obj(tm)
        }
        other => other,
    }
}

/// The fault variant of [`tenant_json_resilience`]: the same row plus
/// the recovery-layer counters. Kept separate so zero-fault documents
/// stay byte-identical to their pre-fault forms (ISSUE 8 determinism
/// contract).
pub(crate) fn tenant_json_faults(t: &TenantOutcome) -> Json {
    match tenant_json_resilience(t) {
        Json::Obj(mut tm) => {
            tm.insert("retries".into(), Json::Num(t.retries as f64));
            tm.insert("hedges".into(), Json::Num(t.hedges as f64));
            tm.insert("hedge_wins".into(), Json::Num(t.hedge_wins as f64));
            tm.insert("cancelled".into(), Json::Num(t.cancelled as f64));
            Json::Obj(tm)
        }
        other => other,
    }
}

/// The generation variant of [`tenant_json`]: the same row plus the
/// token-level SLO and KV-pressure counters. Kept separate so
/// `BENCH_serve.json` / `BENCH_fleet.json` documents stay byte-identical
/// to their pre-generation forms (ISSUE 10 determinism contract);
/// non-finite quantiles serialize as `null` like every other report.
pub(crate) fn tenant_json_gen(t: &TenantOutcome) -> Json {
    match tenant_json(t) {
        Json::Obj(mut tm) => {
            tm.insert("tokens".into(), Json::Num(t.tokens as f64));
            tm.insert("ttft_misses".into(), Json::Num(t.ttft_misses as f64));
            tm.insert("token_misses".into(),
                      Json::Num(t.token_misses as f64));
            tm.insert("evictions".into(), Json::Num(t.evictions as f64));
            tm.insert("preempted_steps".into(),
                      Json::Num(t.preempted_steps as f64));
            tm.insert("ttft_p50_us".into(), Json::Num(t.ttft_p50_us()));
            tm.insert("ttft_p99_us".into(), Json::Num(t.ttft_p99_us()));
            tm.insert("inter_token_p99_us".into(),
                      Json::Num(t.inter_token_p99_us()));
            Json::Obj(tm)
        }
        other => other,
    }
}

/// A scenarios × policies serving comparison (the `BENCH_serve.json`
/// document).
#[derive(Debug, Clone)]
pub struct ServeGridReport {
    /// GPU preset name.
    pub platform: String,
    /// Coordinator served through.
    pub scheduler: String,
    /// Arrival-generation window per cell (us).
    pub duration_us: f64,
    /// Policy names, in run order.
    pub policies: Vec<String>,
    /// Scenario names, in run order.
    pub scenarios: Vec<String>,
    /// Cells in deterministic grid order (scenario-major, then policy).
    pub cells: Vec<ServeReport>,
}

impl ServeGridReport {
    /// The cell for (scenario, policy), if it ran.
    pub fn cell(&self, scenario: &str, policy: AdmissionPolicy)
                -> Option<&ServeReport> {
        self.cells
            .iter()
            .find(|c| c.scenario == scenario && c.policy == policy)
    }

    /// The canonical `BENCH_serve.json` document: sorted keys, no
    /// whitespace, no host-timing fields — byte-deterministic per seed
    /// (schema in EXPERIMENTS.md §Serve).
    pub fn to_json(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("bench".into(), Json::Str("serve".into()));
        obj.insert("platform".into(), Json::Str(self.platform.clone()));
        obj.insert("scheduler".into(), Json::Str(self.scheduler.clone()));
        obj.insert("duration_us".into(), Json::Num(self.duration_us));
        obj.insert(
            "policies".into(),
            Json::Arr(self.policies.iter().cloned().map(Json::Str).collect()),
        );
        obj.insert(
            "scenarios".into(),
            Json::Arr(self.scenarios.iter().cloned().map(Json::Str).collect()),
        );
        obj.insert(
            "cells".into(),
            Json::Arr(self.cells.iter().map(|c| c.to_json_value()).collect()),
        );
        obj.insert("version".into(), Json::Num(1.0));
        Json::Obj(obj).to_canonical_string()
    }
}

/// Serve one scenario through one admission policy until the system
/// drains. Deterministic for a given (scenario, seed, policy, scheduler):
/// the loop advances in simulated time only, and no host timing enters
/// the report.
pub fn run_serve(gpu: &GpuSpec, sc: &ScenarioSpec, opts: &ServeOpts)
                 -> Result<ServeReport, String> {
    validate_admission(&opts.admission)?;
    let mut wl = sc.build();
    if let Some(seed) = opts.seed {
        wl.seed = seed;
    }
    let mut core = DeviceCore::new(gpu, &wl, &opts.scheduler)?;

    let mut ctrl = AdmissionController::new(
        opts.policy,
        opts.admission.clone(),
        &wl,
        core.spec(),
        core.params(),
    );

    let mut rng = Rng::new(wl.seed);
    let mut arrivals = initial_arrivals(&wl, &mut rng);
    let mut tenants = tenant_outcomes(sc, &wl);
    let mut next_id: u64 = 1;

    loop {
        let t_arr = arrivals.peek().map(|(t, _)| t);
        let t_ev = core.next_event_time();
        match (t_arr, t_ev) {
            (None, None) => break,
            (Some(ta), te) if te.map_or(true, |te| ta <= te) => {
                core.advance_to(ta);
                while let Some((t, src)) = arrivals.peek() {
                    if t > ta {
                        break;
                    }
                    arrivals.pop();
                    tenants[src].offered += 1;
                    match ctrl.decide(src, t) {
                        Decision::Admitted => {
                            core.submit(&wl, src, t, next_id);
                            next_id += 1;
                            tenants[src].admitted += 1;
                        }
                        Decision::Shed(_) => {
                            shed_arrival(&wl, src, t, &opts.admission,
                                         &mut tenants, &mut arrivals);
                        }
                    }
                }
                core.sample_queue_depth();
            }
            (_, Some(_)) => {
                core.step(|_id, src, arr, now| {
                    ctrl.on_served(src);
                    record_served(&wl, src, arr, now, &mut tenants,
                                  &mut arrivals);
                });
            }
            // (Some, None) with a failed guard cannot occur: the guard is
            // vacuously true when the engine has no next event.
            _ => unreachable!("serve loop: impossible arrival/event state"),
        }
    }

    let max_normal_queue = core.max_normal_queue();
    let (span_us, metrics) = core.finish();
    Ok(ServeReport {
        scenario: sc.name.clone(),
        platform: gpu.name.clone(),
        scheduler: opts.scheduler.clone(),
        policy: opts.policy,
        seed: wl.seed,
        duration_us: wl.duration_us,
        tenants,
        span_us,
        events: metrics.events,
        max_normal_queue,
        critical_at_risk: ctrl.critical_at_risk(),
    })
}

/// Fresh zeroed per-tenant outcomes for `wl`, labeled through `sc`.
/// Shared with the fleet loop so per-tenant rows mean the same thing in
/// `BENCH_serve.json` and `BENCH_fleet.json`.
pub(crate) fn tenant_outcomes(sc: &ScenarioSpec, wl: &Workload)
                              -> Vec<TenantOutcome> {
    wl.sources
        .iter()
        .enumerate()
        .map(|(i, s)| TenantOutcome {
            source: i,
            label: sc.tenant_label(i),
            model: s.model.name.clone(),
            criticality: s.criticality,
            offered: 0,
            admitted: 0,
            shed: 0,
            served: 0,
            deadline_misses: 0,
            requeues: 0,
            lost: 0,
            retries: 0,
            hedges: 0,
            hedge_wins: 0,
            cancelled: 0,
            latencies_us: Vec::new(),
            tokens: 0,
            ttft_misses: 0,
            token_misses: 0,
            evictions: 0,
            preempted_steps: 0,
            ttft_us: Vec::new(),
            inter_token_us: Vec::new(),
        })
        .collect()
}

/// Account one shed arrival from `src` at time `t`: an open-loop shed
/// request is lost; a shed *closed-loop* client retries after the
/// configured backoff (it has no other way to make progress). Shared
/// with the fleet loop.
pub(crate) fn shed_arrival(
    wl: &Workload,
    src: usize,
    t: f64,
    cfg: &AdmissionConfig,
    tenants: &mut [TenantOutcome],
    arrivals: &mut crate::coordinator::driver::ArrivalQueue,
) {
    tenants[src].shed += 1;
    if wl.sources[src].arrival.is_closed_loop() {
        let retry = t + cfg.shed_backoff_us;
        if retry < wl.duration_us {
            arrivals.push(retry, src);
        }
    }
}

/// Account one served request from `src` (arrived at `arr`, finished at
/// `now`): latency, deadline scoring, and the closed-loop regeneration —
/// the client's next request arrives the moment this one returns (and
/// goes back through admission like any other arrival). Shared with the
/// fleet loop.
pub(crate) fn record_served(
    wl: &Workload,
    src: usize,
    arr: f64,
    now: f64,
    tenants: &mut [TenantOutcome],
    arrivals: &mut crate::coordinator::driver::ArrivalQueue,
) {
    let lat = now - arr;
    let out = &mut tenants[src];
    out.served += 1;
    out.latencies_us.push(lat);
    if wl.sources[src].deadline_us.is_some_and(|d| lat > d) {
        out.deadline_misses += 1;
    }
    if wl.sources[src].arrival.is_closed_loop() && now < wl.duration_us {
        arrivals.push(now, src);
    }
}

/// Run the scenarios × policies grid (scenario-major order) and assemble
/// the [`ServeGridReport`]. `base` provides the scheduler, seed override
/// and admission tunables; its `policy` field is ignored in favor of the
/// `policies` list.
pub fn run_serve_grid(
    gpu: &GpuSpec,
    scenarios: &[ScenarioSpec],
    policies: &[AdmissionPolicy],
    base: &ServeOpts,
) -> Result<ServeGridReport, String> {
    if scenarios.is_empty() {
        return Err("serve grid needs at least one scenario".into());
    }
    if policies.is_empty() {
        return Err("serve grid needs at least one policy".into());
    }
    let mut cells = Vec::with_capacity(scenarios.len() * policies.len());
    for sc in scenarios {
        for &policy in policies {
            let opts = ServeOpts { policy, ..base.clone() };
            cells.push(run_serve(gpu, sc, &opts)?);
        }
    }
    Ok(ServeGridReport {
        platform: gpu.name.clone(),
        scheduler: base.scheduler.clone(),
        duration_us: scenarios[0].duration_us,
        policies: policies.iter().map(|p| p.name().to_string()).collect(),
        scenarios: scenarios.iter().map(|s| s.name.clone()).collect(),
        cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::admission::POLICIES;
    use crate::workloads::scenario;

    const DUR_US: f64 = 20_000.0;

    fn duo() -> ScenarioSpec {
        scenario::by_name("duo-burst", DUR_US).unwrap()
    }

    #[test]
    fn accounting_balances_for_every_policy() {
        for policy in POLICIES {
            let opts = ServeOpts { policy, ..ServeOpts::default() };
            let r = run_serve(&GpuSpec::rtx2060(), &duo(), &opts).unwrap();
            assert_eq!(r.offered(), r.admitted() + r.shed(), "{policy:?}");
            assert!(r.served() <= r.admitted(), "{policy:?}");
            assert_eq!(r.shed_critical(), 0, "{policy:?}");
            assert!(r.served() > 0, "{policy:?}: nothing served");
            assert!(r.events > 0);
            assert!(r.span_us > 0.0);
            for t in &r.tenants {
                assert_eq!(t.offered, t.admitted + t.shed,
                           "{policy:?}/{}", t.label);
            }
        }
    }

    #[test]
    fn open_policy_sheds_nothing() {
        let r = run_serve(&GpuSpec::rtx2060(), &duo(), &ServeOpts::default())
            .unwrap();
        assert_eq!(r.shed(), 0);
        assert_eq!(r.offered(), r.admitted());
    }

    #[test]
    fn grid_report_shape_and_json_parse() {
        let scenarios = vec![duo()];
        let grid = run_serve_grid(&GpuSpec::rtx2060(), &scenarios, &POLICIES,
                                  &ServeOpts::default())
            .unwrap();
        assert_eq!(grid.cells.len(), 3);
        assert!(grid.cell("duo-burst", AdmissionPolicy::TokenBucket)
                    .is_some());
        let j = grid.to_json();
        let doc = crate::runtime::json::parse(&j).expect("valid JSON");
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("serve"));
        assert_eq!(doc.get("cells").and_then(Json::as_arr).map(|a| a.len()),
                   Some(3));
        // Determinism of the document itself.
        let grid2 = run_serve_grid(&GpuSpec::rtx2060(), &scenarios,
                                   &POLICIES, &ServeOpts::default())
            .unwrap();
        assert_eq!(j, grid2.to_json());
    }

    #[test]
    fn rejects_bad_options() {
        let bad_sched =
            ServeOpts { scheduler: "fifo".into(), ..ServeOpts::default() };
        assert!(run_serve(&GpuSpec::rtx2060(), &duo(), &bad_sched).is_err());
        let bad_backoff = ServeOpts {
            admission: AdmissionConfig {
                shed_backoff_us: 0.0,
                ..AdmissionConfig::default()
            },
            ..ServeOpts::default()
        };
        assert!(run_serve(&GpuSpec::rtx2060(), &duo(), &bad_backoff)
            .is_err());
        assert!(run_serve_grid(&GpuSpec::rtx2060(), &[], &POLICIES,
                               &ServeOpts::default())
            .is_err());
        assert!(run_serve_grid(&GpuSpec::rtx2060(), &[duo()], &[],
                               &ServeOpts::default())
            .is_err());
    }

    #[test]
    fn seed_override_changes_a_stochastic_run() {
        let a = run_serve(&GpuSpec::rtx2060(), &duo(),
                          &ServeOpts { seed: Some(11), ..Default::default() })
            .unwrap();
        let b = run_serve(&GpuSpec::rtx2060(), &duo(),
                          &ServeOpts { seed: Some(12), ..Default::default() })
            .unwrap();
        assert_eq!(a.seed, 11);
        assert_eq!(b.seed, 12);
        assert_ne!(a.to_json_value().to_canonical_string(),
                   b.to_json_value().to_canonical_string());
    }
}
