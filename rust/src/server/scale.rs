//! 100k-tenant scale serving (ISSUE 7 tentpole, layer 3): drives a
//! [`ScaleSpec`] population through one device with **constant memory
//! per tenant** — no per-tenant arrival vectors (one lazy
//! [`ArrivalStream`] each, the timing wheel holds exactly one pending
//! arrival per tenant) and no per-tenant latency vectors above
//! [`SKETCH_TENANT_THRESHOLD`](crate::coordinator::stats::SKETCH_TENANT_THRESHOLD)
//! (the P² [`LatencyAccum`] sketch,
//! ~200 bytes flat, replaces the exact list).
//!
//! Determinism contract: a [`ScaleGridReport`] is byte-identical across
//! `--threads` values and repeated runs — no host timing enters the
//! JSON, every tenant draws from its own derived-seed RNG, and grid
//! cells land in position-stable slots. CI pins the 10k-tenant document
//! with a 4-thread-vs-1-thread `cmp`.

use std::collections::BTreeMap;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::coordinator::stats::{LatencyAccum, StreamingSummary};
use crate::coordinator::sweep::{derive_seed, run_indexed};
use crate::gpu::spec::GpuSpec;
use crate::runtime::json::Json;
use crate::runtime::timewheel::TimingWheel;
use crate::server::online::DeviceCore;
use crate::workloads::arrival::ArrivalStream;
use crate::workloads::mdtb::{Source, Workload};
use crate::workloads::models;
use crate::workloads::rng::Rng;
use crate::workloads::scenario::{scale_spec, ScaleSpec};

/// Per-tier aggregate outcome of a scale run (constant memory: counts
/// plus one [`StreamingSummary`]).
#[derive(Debug, Clone)]
pub struct TierOutcome {
    /// Tier name (from the [`ScaleSpec`] tier table).
    pub name: String,
    /// Tenants in the tier.
    pub tenants: usize,
    /// Arrivals delivered for the tier.
    pub offered: u64,
    /// Requests completed for the tier.
    pub served: u64,
    /// Completions past the tier deadline.
    pub deadline_misses: u64,
    /// Streaming latency summary (mean exact; p50/p99 are P² estimates
    /// once the tier exceeds five samples).
    pub latency: StreamingSummary,
}

/// One scale-run cell (one tenant count on one device).
#[derive(Debug, Clone)]
pub struct ScaleReport {
    /// Scenario name (`scale-{tenants}t`).
    pub name: String,
    /// GPU preset name.
    pub platform: String,
    /// Coordinator served through.
    pub scheduler: String,
    /// Tenant count.
    pub tenants: usize,
    /// Arrival window (us).
    pub duration_us: f64,
    /// Master seed.
    pub seed: u64,
    /// Aggregate offered load (Hz) of the spec.
    pub aggregate_hz: f64,
    /// Simulated span until drain (us).
    pub span_us: f64,
    /// Simulator events processed by the engine.
    pub events: u64,
    /// Total arrivals delivered.
    pub offered: u64,
    /// Total requests completed.
    pub served: u64,
    /// Total completions past their tier deadline.
    pub deadline_misses: u64,
    /// Tenants whose latency accounting uses the P² sketch (all of
    /// them above
    /// [`SKETCH_TENANT_THRESHOLD`](crate::coordinator::stats::SKETCH_TENANT_THRESHOLD),
    /// none below).
    pub sketch_tenants: usize,
    /// Latency-accounting bytes per tenant — the quantity the sketch
    /// holds constant while the exact representation grows with
    /// served requests.
    pub bytes_per_tenant: f64,
    /// Highest per-tenant p99 latency (us) among tenants that served
    /// at least one request (NaN, serialized `null`, if none did).
    pub worst_tenant_p99_us: f64,
    /// Per-tier aggregates, in tier-table order.
    pub tiers: Vec<TierOutcome>,
}

impl ScaleReport {
    /// This cell as a canonical-JSON value (one `cells[]` row of
    /// `BENCH_scale.json`). Deterministic: no host-timing field.
    pub fn to_json_value(&self) -> Json {
        let num = Json::Num;
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.clone()));
        m.insert("platform".into(), Json::Str(self.platform.clone()));
        m.insert("scheduler".into(), Json::Str(self.scheduler.clone()));
        m.insert("tenants".into(), num(self.tenants as f64));
        m.insert("duration_us".into(), num(self.duration_us));
        m.insert("seed".into(), num(self.seed as f64));
        m.insert("aggregate_hz".into(), num(self.aggregate_hz));
        m.insert("span_us".into(), num(self.span_us));
        m.insert("events".into(), num(self.events as f64));
        m.insert("offered".into(), num(self.offered as f64));
        m.insert("served".into(), num(self.served as f64));
        m.insert("deadline_misses".into(),
                 num(self.deadline_misses as f64));
        m.insert("sketch_tenants".into(), num(self.sketch_tenants as f64));
        m.insert("bytes_per_tenant".into(), num(self.bytes_per_tenant));
        m.insert("worst_tenant_p99_us".into(),
                 num(self.worst_tenant_p99_us));
        m.insert(
            "tiers".into(),
            Json::Arr(
                self.tiers
                    .iter()
                    .map(|t| {
                        let mut tm = BTreeMap::new();
                        tm.insert("name".into(), Json::Str(t.name.clone()));
                        tm.insert("tenants".into(),
                                  num(t.tenants as f64));
                        tm.insert("offered".into(), num(t.offered as f64));
                        tm.insert("served".into(), num(t.served as f64));
                        tm.insert("deadline_misses".into(),
                                  num(t.deadline_misses as f64));
                        tm.insert("mean_us".into(), num(t.latency.mean()));
                        tm.insert("p50_us".into(), num(t.latency.p50()));
                        tm.insert("p99_us".into(), num(t.latency.p99()));
                        Json::Obj(tm)
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }
}

/// The tenant-count grid (the `BENCH_scale.json` document).
#[derive(Debug, Clone)]
pub struct ScaleGridReport {
    /// GPU preset name.
    pub platform: String,
    /// Coordinator served through.
    pub scheduler: String,
    /// Arrival window per cell (us).
    pub duration_us: f64,
    /// Tenant counts, in run order.
    pub tenant_counts: Vec<usize>,
    /// Cells in tenant-count order regardless of thread interleaving.
    pub cells: Vec<ScaleReport>,
}

impl ScaleGridReport {
    /// The cell for a tenant count, if it ran.
    pub fn cell(&self, tenants: usize) -> Option<&ScaleReport> {
        self.cells.iter().find(|c| c.tenants == tenants)
    }

    /// The canonical `BENCH_scale.json` document: sorted keys, no
    /// whitespace, no host timing — byte-deterministic across thread
    /// counts and repeats (schema in EXPERIMENTS.md §Scale).
    pub fn to_json(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("bench".into(), Json::Str("scale".into()));
        obj.insert("platform".into(), Json::Str(self.platform.clone()));
        obj.insert("scheduler".into(), Json::Str(self.scheduler.clone()));
        obj.insert("duration_us".into(), Json::Num(self.duration_us));
        obj.insert(
            "tenant_counts".into(),
            Json::Arr(
                self.tenant_counts
                    .iter()
                    .map(|t| Json::Num(*t as f64))
                    .collect(),
            ),
        );
        obj.insert(
            "cells".into(),
            Json::Arr(self.cells.iter().map(|c| c.to_json_value()).collect()),
        );
        obj.insert("version".into(), Json::Num(1.0));
        Json::Obj(obj).to_canonical_string()
    }
}

/// Materialize the runnable [`Workload`] of a compiled scale spec with
/// **shared model descriptors**: each distinct model name resolves to
/// one `Arc`, cloned across its tenants, so 100k tenants cost O(models)
/// model memory and [`DeviceCore::new`] interns each model once (its
/// pointer-keyed cache hits on the shared `Arc`).
fn build_scale_workload(spec: &ScaleSpec) -> Workload {
    let sc = spec.compile();
    let mut cache: HashMap<&str, Arc<models::ModelDesc>> = HashMap::new();
    let sources = sc
        .sources
        .iter()
        .map(|s| Source {
            model: cache
                .entry(s.model.as_str())
                .or_insert_with(|| {
                    Arc::new(models::by_name(&s.model).unwrap_or_else(
                        || {
                            panic!(
                                "unknown model {} in scale spec {}",
                                s.model, spec.name
                            )
                        },
                    ))
                })
                .clone(),
            arrival: s.arrival.clone(),
            criticality: s.criticality,
            deadline_us: s.deadline_us,
        })
        .collect();
    Workload {
        name: sc.name.clone(),
        sources,
        duration_us: sc.duration_us,
        seed: sc.seed,
    }
}

/// Per-tenant arrival RNG seed: derived twice from the master seed so
/// it never collides with the tenant's rate-weight draw
/// (`derive_seed(seed, i + 1)`, see `ScaleSpec::tenant_weight`) —
/// a tenant's first inter-arrival gap must not be a function of its
/// rate weight.
fn arrival_seed(master: u64, tenant: usize) -> u64 {
    derive_seed(derive_seed(master, tenant as u32 + 1), 1)
}

/// Run one scale cell: `spec`'s population on one `gpu` device under
/// `scheduler`, pulling arrivals lazily until the window closes and the
/// device drains. Deterministic for (spec, gpu, scheduler).
pub fn run_scale(gpu: &GpuSpec, spec: &ScaleSpec, scheduler: &str)
                 -> Result<ScaleReport, String> {
    spec.assert_valid();
    let wl = build_scale_workload(spec);
    let n = wl.sources.len();
    let mut core = DeviceCore::new(gpu, &wl, scheduler)?;

    // One lazy stream + one RNG per tenant; the wheel holds at most one
    // pending arrival per tenant, so queue memory is O(tenants) flat
    // and never O(total arrivals).
    let mut streams: Vec<ArrivalStream> = wl
        .sources
        .iter()
        .map(|s| s.arrival.stream(wl.duration_us))
        .collect();
    let mut rngs: Vec<Rng> = (0..n)
        .map(|i| Rng::new(arrival_seed(wl.seed, i)))
        .collect();
    let mut wheel = TimingWheel::new();
    for i in 0..n {
        if let Some(t) = streams[i].next(&mut rngs[i]) {
            wheel.push(t, i);
        }
    }

    // Per-tenant accounting: counters plus a LatencyAccum that switches
    // to the constant-size sketch above the committed threshold.
    let mut accums: Vec<LatencyAccum> =
        (0..n).map(|_| LatencyAccum::for_tenants(n)).collect();
    let mut offered = vec![0u64; n];
    let mut served = vec![0u64; n];
    let mut misses = vec![0u64; n];
    let counts = spec.tier_counts();
    let tier_of: Vec<usize> = counts
        .iter()
        .enumerate()
        .flat_map(|(t, c)| std::iter::repeat(t).take(*c))
        .collect();
    let mut tier_lat: Vec<StreamingSummary> =
        (0..counts.len()).map(|_| StreamingSummary::new()).collect();

    let mut next_id: u64 = 1;
    loop {
        let t_arr = wheel.peek().map(|(t, _)| t);
        let t_ev = core.next_event_time();
        match (t_arr, t_ev) {
            (None, None) => break,
            (Some(ta), te) if te.map_or(true, |te| ta <= te) => {
                core.advance_to(ta);
                while let Some((t, src)) = wheel.peek() {
                    if t > ta {
                        break;
                    }
                    wheel.pop();
                    offered[src] += 1;
                    core.submit(&wl, src, t, next_id);
                    next_id += 1;
                    // Streams are strictly in-order per tenant, so the
                    // replacement arrival can never precede `t`.
                    if let Some(nt) = streams[src].next(&mut rngs[src]) {
                        wheel.push(nt, src);
                    }
                }
                core.sample_queue_depth();
            }
            (_, Some(_)) => {
                core.step(|_id, src, arr, now| {
                    let lat = now - arr;
                    served[src] += 1;
                    accums[src].record(lat);
                    tier_lat[tier_of[src]].record(lat);
                    if wl.sources[src].deadline_us.is_some_and(|d| lat > d)
                    {
                        misses[src] += 1;
                    }
                });
            }
            _ => unreachable!("scale loop: impossible arrival/event state"),
        }
    }

    let (span_us, metrics) = core.finish();

    let sketch_tenants =
        accums.iter().filter(|a| a.is_sketch()).count();
    let bytes: usize = accums.iter().map(|a| a.bytes()).sum();
    let worst_tenant_p99_us = accums
        .iter()
        .filter(|a| a.count() > 0)
        .map(|a| a.p99())
        .fold(f64::NAN, |acc, p| {
            if acc.is_nan() || p > acc { p } else { acc }
        });

    let mut tiers = Vec::with_capacity(counts.len());
    let mut idx = 0usize;
    for (t, c) in counts.iter().enumerate() {
        let range = idx..idx + c;
        tiers.push(TierOutcome {
            name: spec.tiers[t].name.clone(),
            tenants: *c,
            offered: offered[range.clone()].iter().sum(),
            served: served[range.clone()].iter().sum(),
            deadline_misses: misses[range].iter().sum(),
            latency: tier_lat[t].clone(),
        });
        idx += c;
    }

    Ok(ScaleReport {
        name: spec.name.clone(),
        platform: gpu.name.clone(),
        scheduler: scheduler.to_string(),
        tenants: n,
        duration_us: wl.duration_us,
        seed: wl.seed,
        aggregate_hz: spec.aggregate_hz,
        span_us,
        events: metrics.events,
        offered: offered.iter().sum(),
        served: served.iter().sum(),
        deadline_misses: misses.iter().sum(),
        sketch_tenants,
        bytes_per_tenant: bytes as f64 / n as f64,
        worst_tenant_p99_us,
        tiers,
    })
}

/// Run the tenant-count grid (the standard [`scale_spec`] preset per
/// count) across a worker pool. Cells land in position-stable slots, so
/// the report — and its `BENCH_scale.json` bytes — are identical for
/// any `threads` value.
pub fn run_scale_grid(gpu: &GpuSpec, tenant_counts: &[usize],
                      duration_us: f64, scheduler: &str, threads: usize)
                      -> Result<ScaleGridReport, String> {
    let n = tenant_counts.len();
    let slots: Vec<Mutex<Option<Result<ScaleReport, String>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    run_indexed(n, threads, |i| {
        let spec = scale_spec(tenant_counts[i], duration_us);
        let r = run_scale(gpu, &spec, scheduler);
        *slots[i].lock().unwrap() = Some(r);
    });
    let mut cells = Vec::with_capacity(n);
    for s in slots {
        cells.push(s.into_inner().unwrap().expect("cell ran")?);
    }
    Ok(ScaleGridReport {
        platform: gpu.name.clone(),
        scheduler: scheduler.to_string(),
        duration_us,
        tenant_counts: tenant_counts.to_vec(),
        cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::stats::SKETCH_TENANT_THRESHOLD;

    fn gpu() -> GpuSpec {
        GpuSpec::by_name("rtx2060").unwrap()
    }

    #[test]
    fn small_scale_run_serves_everything_exactly() {
        // 10 tenants sit below the sketch threshold: every tenant keeps
        // exact latencies and the run drains fully.
        let spec = scale_spec(10, 50_000.0);
        assert!(spec.tenants < SKETCH_TENANT_THRESHOLD);
        let r = run_scale(&gpu(), &spec, "miriam").unwrap();
        assert_eq!(r.tenants, 10);
        assert_eq!(r.sketch_tenants, 0);
        assert!(r.offered > 0, "no arrivals in {}us", r.duration_us);
        assert_eq!(r.served, r.offered);
        assert_eq!(r.tiers.len(), 3);
        let tier_offered: u64 = r.tiers.iter().map(|t| t.offered).sum();
        assert_eq!(tier_offered, r.offered);
        assert!(r.span_us >= 0.0);
    }

    #[test]
    fn large_scale_run_uses_sketches_and_constant_tenant_bytes() {
        let spec = scale_spec(500, 50_000.0);
        assert!(spec.tenants >= SKETCH_TENANT_THRESHOLD);
        let r = run_scale(&gpu(), &spec, "miriam").unwrap();
        assert_eq!(r.sketch_tenants, 500);
        // Sketch accounting is a flat struct: per-tenant bytes must not
        // exceed one LatencyAccum regardless of how many were served.
        assert!(
            r.bytes_per_tenant
                <= std::mem::size_of::<LatencyAccum>() as f64,
            "bytes/tenant {}",
            r.bytes_per_tenant
        );
        assert_eq!(r.served, r.offered);
    }

    #[test]
    fn scale_run_is_deterministic() {
        let spec = scale_spec(200, 30_000.0);
        let a = run_scale(&gpu(), &spec, "miriam").unwrap();
        let b = run_scale(&gpu(), &spec, "miriam").unwrap();
        assert_eq!(a.to_json_value().to_canonical_string(),
                   b.to_json_value().to_canonical_string());
    }

    #[test]
    fn grid_is_thread_invariant() {
        let counts = [50usize, 200];
        let a = run_scale_grid(&gpu(), &counts, 20_000.0, "miriam", 1)
            .unwrap();
        let b = run_scale_grid(&gpu(), &counts, 20_000.0, "miriam", 4)
            .unwrap();
        assert_eq!(a.to_json(), b.to_json());
        assert!(a.cell(50).is_some() && a.cell(200).is_some());
        let doc = a.to_json();
        assert!(doc.contains("\"bench\":\"scale\""));
        assert!(!doc.contains("inf") && !doc.contains("NaN"));
    }

    #[test]
    fn unknown_scheduler_is_an_error() {
        let spec = scale_spec(10, 10_000.0);
        assert!(run_scale(&gpu(), &spec, "nope").is_err());
    }
}
