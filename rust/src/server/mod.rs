//! The serving layer: a criticality-aware request router in front of the
//! PJRT runtime.
//!
//! This is the deployment face of Miriam: clients submit inference
//! requests tagged critical/normal; critical requests always jump the
//! queue (the software analog of the critical stream), normal requests are
//! served best-effort. Real model compute runs through the AOT artifacts
//! on the PJRT CPU client — Python is never involved.
//!
//! On a physical edge GPU the elastic-kernel coordinator would sit between
//! the router and the device; here its scheduling behaviour is exercised
//! by the simulator (`crate::coordinator`), while this server proves the
//! end-to-end artifact path (examples/serve_e2e.rs).
//!
//! [`online`] is where the two faces meet (ISSUE 4): a simulated-time
//! serving loop that runs open-loop scenario arrivals through an
//! admission controller into the live coordinator, with per-tenant SLO
//! accounting (`miriam serve-sim`). [`scale`] (ISSUE 7) stretches that
//! loop to 100k-tenant populations with lazy arrival streams and
//! streaming quantile sketches (`miriam scale-sim`). [`gen`] (ISSUE 10)
//! serves autoregressive prefill/decode requests through the same core:
//! per-step graph resubmission, KV-cache residency with memory-pressure
//! eviction, and token-level TTFT / per-token SLOs (`miriam gen-sim`).

pub mod gen;
pub mod online;
pub mod scale;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::gpu::kernel::Criticality;
use crate::runtime::{Manifest, Runtime};

/// A model-execution backend: maps (model name, input) to an output
/// buffer. The PJRT-backed executor is built by [`Server::start`]; tests
/// inject synthetic executors through [`Server::start_with_executor`] so
/// the queue discipline is exercised without the `pjrt` feature.
///
/// Deliberately not `Send`: the executor is constructed *inside* the
/// worker thread (only the factory crosses threads), matching the
/// non-`Send` XLA client.
pub trait Executor {
    fn execute(&mut self, model: &str, input: &[f32]) -> Result<Vec<f32>>;
}

impl<F> Executor for F
where
    F: FnMut(&str, &[f32]) -> Result<Vec<f32>>,
{
    fn execute(&mut self, model: &str, input: &[f32]) -> Result<Vec<f32>> {
        self(model, input)
    }
}

/// One inference request.
pub struct InferRequest {
    /// Artifact/model name to execute.
    pub model: String,
    /// Queue class: critical requests jump the queue.
    pub criticality: Criticality,
    /// Flat f32 input buffer.
    pub input: Vec<f32>,
    /// Reply channel.
    pub reply: std::sync::mpsc::Sender<InferReply>,
}

/// The server's answer.
#[derive(Debug, Clone)]
pub struct InferReply {
    /// Flattened output buffer (empty on error).
    pub output: Vec<f32>,
    /// Queueing + execution latency observed by the server (us).
    pub latency_us: f64,
    /// Whether execution succeeded.
    pub ok: bool,
    /// The error message when `ok` is false.
    pub error: Option<String>,
}

#[derive(Default)]
struct Queues {
    critical: VecDeque<(InferRequest, Instant)>,
    normal: VecDeque<(InferRequest, Instant)>,
    shutdown: bool,
}

/// Aggregate serving statistics.
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Critical requests served successfully.
    pub served_critical: AtomicU64,
    /// Normal requests served successfully.
    pub served_normal: AtomicU64,
    /// Requests that failed in the executor.
    pub errors: AtomicU64,
    /// Sum of latencies (us) per class, for means.
    pub critical_latency_us_sum: AtomicU64,
    /// Normal-class latency sum (us).
    pub normal_latency_us_sum: AtomicU64,
}

impl ServerStats {
    /// Mean served critical latency (us; 0 when nothing served).
    pub fn mean_critical_latency_us(&self) -> f64 {
        let n = self.served_critical.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.critical_latency_us_sum.load(Ordering::Relaxed) as f64 / n as f64
    }
    /// Mean served normal latency (us; 0 when nothing served).
    pub fn mean_normal_latency_us(&self) -> f64 {
        let n = self.served_normal.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.normal_latency_us_sum.load(Ordering::Relaxed) as f64 / n as f64
    }
}

/// Handle for submitting requests.
#[derive(Clone)]
pub struct ServerHandle {
    queues: Arc<(Mutex<Queues>, Condvar)>,
    /// Live serving counters, shared with the worker.
    pub stats: Arc<ServerStats>,
}

impl ServerHandle {
    /// Enqueue a request (critical requests are drained first).
    pub fn submit(&self, req: InferRequest) {
        let (lock, cv) = &*self.queues;
        let mut q = lock.lock().unwrap();
        match req.criticality {
            Criticality::Critical => q.critical.push_back((req, Instant::now())),
            Criticality::Normal => q.normal.push_back((req, Instant::now())),
        }
        cv.notify_one();
    }

    /// Convenience: submit and wait for the reply.
    pub fn infer(&self, model: &str, criticality: Criticality,
                 input: Vec<f32>) -> InferReply {
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(InferRequest {
            model: model.to_string(),
            criticality,
            input,
            reply: tx,
        });
        rx.recv().expect("server dropped reply channel")
    }

    /// Signal shutdown (worker exits after draining nothing more).
    pub fn shutdown(&self) {
        let (lock, cv) = &*self.queues;
        lock.lock().unwrap().shutdown = true;
        cv.notify_all();
    }
}

/// The serving loop. Owns the PJRT runtime on a dedicated thread (the XLA
/// client is not `Send`-friendly; all execution funnels through here).
pub struct Server {
    /// Handle for submitting requests and reading stats.
    pub handle: ServerHandle,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the server over an artifact directory; pre-compiles `models`.
    ///
    /// The PJRT client wraps non-`Send` FFI handles, so the runtime is
    /// constructed *inside* the worker thread; startup errors are reported
    /// back over a channel before the first request is accepted.
    pub fn start(artifact_dir: impl Into<std::path::PathBuf>,
                 models: &[String]) -> Result<Self> {
        let dir = artifact_dir.into();
        let models: Vec<String> = models.to_vec();
        Self::start_with_executor(move || -> Result<Box<dyn Executor>> {
            let mut runtime = Manifest::load(&dir)
                .and_then(Runtime::new)
                .and_then(|mut rt| {
                    for m in &models {
                        rt.load(m)?;
                    }
                    Ok(rt)
                })?;
            Ok(Box::new(move |model: &str, input: &[f32]| {
                runtime.load(model)?.run_f32(&[input.to_vec()])
            }))
        })
    }

    /// Start the serving loop over an arbitrary [`Executor`]. `make` runs
    /// once on the worker thread to build the executor (so non-`Send`
    /// backends work); a factory error is propagated out of `start_with_executor`
    /// before any request is accepted.
    pub fn start_with_executor<F>(make: F) -> Result<Self>
    where
        F: FnOnce() -> Result<Box<dyn Executor>> + Send + 'static,
    {
        let queues = Arc::new((Mutex::new(Queues::default()), Condvar::new()));
        let stats = Arc::new(ServerStats::default());
        let handle = ServerHandle { queues: queues.clone(), stats: stats.clone() };
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();

        let worker = std::thread::spawn(move || {
            let mut exec = match make() {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let (lock, cv) = &*queues;
            loop {
                let (req, enq) = {
                    let mut q = lock.lock().unwrap();
                    loop {
                        if let Some(r) = q.critical.pop_front() {
                            break r;
                        }
                        if let Some(r) = q.normal.pop_front() {
                            break r;
                        }
                        if q.shutdown {
                            return;
                        }
                        q = cv.wait(q).unwrap();
                    }
                };
                let crit = req.criticality;
                let result = exec.execute(&req.model, &req.input);
                let latency_us = enq.elapsed().as_secs_f64() * 1e6;
                let reply = match result {
                    Ok(output) => InferReply {
                        output,
                        latency_us,
                        ok: true,
                        error: None,
                    },
                    Err(e) => {
                        stats.errors.fetch_add(1, Ordering::Relaxed);
                        InferReply {
                            output: Vec::new(),
                            latency_us,
                            ok: false,
                            error: Some(format!("{e:#}")),
                        }
                    }
                };
                if reply.ok {
                    match crit {
                        Criticality::Critical => {
                            stats.served_critical.fetch_add(1, Ordering::Relaxed);
                            stats
                                .critical_latency_us_sum
                                .fetch_add(latency_us as u64, Ordering::Relaxed);
                        }
                        Criticality::Normal => {
                            stats.served_normal.fetch_add(1, Ordering::Relaxed);
                            stats
                                .normal_latency_us_sum
                                .fetch_add(latency_us as u64, Ordering::Relaxed);
                        }
                    }
                }
                let _ = req.reply.send(reply);
            }
        });
        // Propagate startup failure (bad artifacts, PJRT init error).
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server worker died during startup"))??;
        Ok(Server { handle, worker: Some(worker) })
    }

    /// Shut down and join the worker.
    pub fn stop(mut self) {
        self.handle.shutdown();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.handle.shutdown();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}
