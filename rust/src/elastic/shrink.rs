//! Workload-balance-guided design-space shrinking (paper §6.3).
//!
//! The full (elastic grid x elastic block) space is huge (the paper counts
//! 2.2e25 feasible schedules for AlexNet's conv kernels). Miriam prunes it
//! offline with:
//!
//! * the two hard constraints of Eq. 2 (inter-SM block-count fit and
//!   intra-SM thread fit against a representative critical co-runner),
//! * `WIScore` (Eq. 4) — workload-imbalance metric in [0, 1],
//! * `OScore` (Eq. 5) — a 0/1 launch-overhead gate,
//!
//! keeping the top `keep_frac` (paper: 20%) of candidates by
//! `WIScore * OScore`.


use crate::elastic::block::block_size_options;
use crate::elastic::candidate::Candidate;
use crate::elastic::grid::slicing_plan;
use crate::gpu::kernel::KernelDesc;
use crate::gpu::spec::GpuSpec;

/// Launch geometry of a representative critical co-runner
/// (`N_blk_rt`, `S_blk_rt` in Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CriticalProfile {
    /// Grid size of the critical co-runner (`N_blk_rt`).
    pub n_blk_rt: u32,
    /// Block threads of the critical co-runner (`S_blk_rt`).
    pub s_blk_rt: u32,
}

impl CriticalProfile {
    /// The profile a kernel presents when launched untransformed.
    pub fn from_kernel(k: &KernelDesc) -> Self {
        CriticalProfile { n_blk_rt: k.grid, s_blk_rt: k.block_threads }
    }
}

/// Shrinking configuration.
#[derive(Debug, Clone)]
pub struct ShrinkConfig {
    /// Fraction of (feasible) candidates kept (paper §6.3: top 20%).
    pub keep_frac: f64,
    /// Maximum acceptable cumulative extra launch overhead per kernel, us
    /// (the `MAX` bar of Eq. 5; §8.6 measures <15us per-launch padding
    /// overheads, so the default allows a modest multiple of that).
    pub max_overhead_us: f64,
}

impl Default for ShrinkConfig {
    fn default() -> Self {
        ShrinkConfig { keep_frac: 0.2, max_overhead_us: 200.0 }
    }
}

/// Eq. 2, first constraint: shard block count must fit the SMs left after
/// the critical kernel's partial wave (`N_blk_be <= N_SM - N_blk_rt mod
/// N_SM`).
pub fn fits_inter_sm(c: &Candidate, crit: &CriticalProfile, spec: &GpuSpec) -> bool {
    let leftover = spec.num_sms - crit.n_blk_rt % spec.num_sms;
    c.n_blocks <= leftover
}

/// Eq. 2, second constraint: elastic block threads must fit the intra-SM
/// thread slots left by a resident critical block
/// (`S_blk_be <= L_threads - S_blk_rt`).
pub fn fits_intra_sm(c: &Candidate, crit: &CriticalProfile, spec: &GpuSpec) -> bool {
    crit.s_blk_rt < spec.max_threads_per_sm
        && c.block_threads <= spec.max_threads_per_sm - crit.s_blk_rt
}

/// Both Eq. 2 constraints.
pub fn feasible(c: &Candidate, crit: &CriticalProfile, spec: &GpuSpec) -> bool {
    fits_inter_sm(c, crit, spec) && fits_intra_sm(c, crit, spec)
}

/// `WIScore` (Eq. 4): workload-imbalance metric in [0, 1]; higher = the
/// combined residency packs SMs more fully/evenly.
/// `((N_blk_rt mod N_SM + N_blk_be) / N_SM) * ((S_blk_rt + S_blk_be) /
/// L_threads)` — the paper's formula (its second term is printed with a
/// typo, `S_blk_be + S_blk_be`; the surrounding text makes clear it
/// combines the critical and elastic block sizes).
pub fn wiscore(c: &Candidate, crit: &CriticalProfile, spec: &GpuSpec) -> f64 {
    let blocks = (crit.n_blk_rt % spec.num_sms + c.n_blocks) as f64
        / spec.num_sms as f64;
    let threads = (crit.s_blk_rt + c.block_threads) as f64
        / spec.max_threads_per_sm as f64;
    (blocks * threads).clamp(0.0, 1.0)
}

/// `OScore` (Eq. 5): 1 if the cumulative extra launch overhead of the
/// candidate's sharding stays under the acceptable bar, else 0. The extra
/// overhead is `(num_shards - 1) * kernel_launch_us` — the launches the
/// original (single-launch) kernel did not pay.
pub fn oscore(c: &Candidate, kernel: &KernelDesc, spec: &GpuSpec,
              max_overhead_us: f64) -> f64 {
    let extra = (c.num_shards(kernel) as f64 - 1.0) * spec.kernel_launch_us;
    if extra < max_overhead_us {
        1.0
    } else {
        0.0
    }
}

/// Result of shrinking one kernel's design space.
#[derive(Debug, Clone)]
pub struct ShrunkSpace {
    /// Size of the full enumerated space.
    pub total: usize,
    /// Candidates surviving Eq. 2 + OScore, ranked by WIScore desc, top
    /// `keep_frac` kept.
    pub kept: Vec<Candidate>,
    /// Pruned fraction in [0, 1] (Fig. 10 reports 84%–95.2%).
    pub pruned_frac: f64,
}

/// Enumerate the (slicing plan x block sizes) space for `kernel` and shrink
/// it against representative critical profiles (the best score across
/// profiles is used — a candidate only needs one co-running context in
/// which it packs well).
pub fn shrink_design_space(kernel: &KernelDesc, crits: &[CriticalProfile],
                           spec: &GpuSpec, cfg: &ShrinkConfig) -> ShrunkSpace {
    let mut scored: Vec<(Candidate, f64)> = Vec::new();
    let mut total = 0usize;
    for n_blocks in slicing_plan(kernel.grid) {
        for block_threads in block_size_options(kernel.block_threads,
                                                spec.warp_size) {
            let c = Candidate { n_blocks, block_threads };
            total += 1;
            let os = oscore(&c, kernel, spec, cfg.max_overhead_us);
            if os == 0.0 {
                continue;
            }
            // Best WIScore across the representative critical contexts the
            // candidate is feasible for.
            let best = crits
                .iter()
                .filter(|cr| feasible(&c, cr, spec))
                .map(|cr| wiscore(&c, cr, spec))
                .fold(f64::NEG_INFINITY, f64::max);
            if best.is_finite() {
                scored.push((c, best * os));
            }
        }
    }
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap()
        .then_with(|| (a.0.n_blocks, a.0.block_threads)
            .cmp(&(b.0.n_blocks, b.0.block_threads))));
    let keep = ((scored.len() as f64 * cfg.keep_frac).ceil() as usize)
        .max(1)
        .min(scored.len());
    let kept: Vec<Candidate> = scored.into_iter().take(keep).map(|s| s.0).collect();
    let pruned_frac = if total > 0 {
        1.0 - kept.len() as f64 / total as f64
    } else {
        0.0
    };
    ShrunkSpace { total, kept, pruned_frac }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kernel() -> KernelDesc {
        KernelDesc {
            name: "t/k".into(),
            grid: 64,
            block_threads: 256,
            smem_per_block: 4096,
            regs_per_thread: 32,
            flops: 1e7,
            bytes: 1e5,
        }
    }

    fn crit() -> CriticalProfile {
        CriticalProfile { n_blk_rt: 50, s_blk_rt: 512 }
    }

    #[test]
    fn eq2_inter_sm() {
        let spec = GpuSpec::rtx2060(); // 30 SMs
        // 50 mod 30 = 20 resident-wave blocks; leftover = 10 SMs.
        let cr = crit();
        assert!(fits_inter_sm(&Candidate { n_blocks: 10, block_threads: 32 }, &cr, &spec));
        assert!(!fits_inter_sm(&Candidate { n_blocks: 11, block_threads: 32 }, &cr, &spec));
    }

    #[test]
    fn eq2_intra_sm() {
        let spec = GpuSpec::rtx2060(); // 1024 threads/SM
        let cr = crit(); // 512-thread critical blocks
        assert!(fits_intra_sm(&Candidate { n_blocks: 1, block_threads: 512 }, &cr, &spec));
        assert!(!fits_intra_sm(&Candidate { n_blocks: 1, block_threads: 513 }, &cr, &spec));
        // Full-SM critical block leaves no room at all.
        let full = CriticalProfile { n_blk_rt: 30, s_blk_rt: 1024 };
        assert!(!fits_intra_sm(&Candidate { n_blocks: 1, block_threads: 1 }, &full, &spec));
    }

    #[test]
    fn wiscore_in_unit_range_and_monotone() {
        let spec = GpuSpec::rtx2060();
        let cr = crit();
        let small = wiscore(&Candidate { n_blocks: 1, block_threads: 32 }, &cr, &spec);
        let big = wiscore(&Candidate { n_blocks: 10, block_threads: 512 }, &cr, &spec);
        assert!(small >= 0.0 && small <= 1.0);
        assert!(big >= 0.0 && big <= 1.0);
        assert!(big > small, "fuller packing scores higher");
    }

    #[test]
    fn oscore_gates_excessive_sharding() {
        let spec = GpuSpec::rtx2060(); // 5us launch overhead
        let k = kernel(); // 64 blocks
        let cfg_max = 200.0;
        // 64 shards of 1 block: extra overhead 63*5 = 315us > 200 -> 0.
        assert_eq!(oscore(&Candidate { n_blocks: 1, block_threads: 32 }, &k, &spec, cfg_max), 0.0);
        // 2 shards: 5us extra -> 1.
        assert_eq!(oscore(&Candidate { n_blocks: 32, block_threads: 32 }, &k, &spec, cfg_max), 1.0);
    }

    #[test]
    fn shrink_keeps_top_fraction() {
        let spec = GpuSpec::rtx2060();
        let k = kernel();
        let out = shrink_design_space(&k, &[crit()], &spec,
                                      &ShrinkConfig::default());
        assert!(out.total > 0);
        assert!(!out.kept.is_empty());
        assert!(out.pruned_frac > 0.5, "pruned {}", out.pruned_frac);
        // Everything kept satisfies Eq. 2 for the profile.
        for c in &out.kept {
            assert!(feasible(c, &crit(), &spec), "{c:?}");
        }
    }

    #[test]
    fn shrink_empty_when_nothing_feasible_keeps_none() {
        let spec = GpuSpec::rtx2060();
        let k = kernel();
        // Critical occupies every thread slot: nothing fits intra-SM.
        let full = CriticalProfile { n_blk_rt: 30, s_blk_rt: 1024 };
        let out = shrink_design_space(&k, &[full], &spec,
                                      &ShrinkConfig::default());
        assert!(out.kept.is_empty());
        assert!((out.pruned_frac - 1.0).abs() < 1e-9);
    }

    #[test]
    fn paper_fig10_range_on_representative_kernels() {
        // Pruned fraction should land in the order of Fig. 10's 84–95.2%
        // (top-20% keep of the feasible subset of the full space).
        let spec = GpuSpec::rtx2060();
        let k = KernelDesc { grid: 128, block_threads: 512, ..kernel() };
        let crits = [
            CriticalProfile { n_blk_rt: 40, s_blk_rt: 256 },
            CriticalProfile { n_blk_rt: 75, s_blk_rt: 128 },
        ];
        let out = shrink_design_space(&k, &crits, &spec, &ShrinkConfig::default());
        assert!(out.pruned_frac >= 0.8, "pruned {}", out.pruned_frac);
        assert!(out.pruned_frac < 1.0);
    }
}
