//! Elastic grid: the dichotomy slicing plan (paper Eq. 1).
//!
//! For a kernel with `M` thread blocks, the admissible shard sizes are
//! `S(K) = (M/2^n, M/2^{n-1}, ..., M)` where `n` is the largest power of
//! two dividing `M`. Slicing a kernel into independent launches of that
//! size lets the GPU interleave critical kernels between shards,
//! attacking *inter-SM* memory contention (§6.2).
//!
//! Mirrors `python/compile/kernels/elastic_matmul.py::slicing_plan` — the
//! two implementations are kept in lock-step by tests on both sides.

/// The dichotomy slicing plan `S(K)`: admissible shard sizes (in thread
/// blocks), ascending. Always contains `m` itself; never empty.
pub fn slicing_plan(m: u32) -> Vec<u32> {
    assert!(m > 0, "kernel must have at least one block");
    let mut n = 0u32;
    while m % 2u32.pow(n + 1) == 0 {
        n += 1;
    }
    (0..=n).rev().map(|i| m / 2u32.pow(i)).collect()
}

/// Number of shards when slicing `m` blocks at shard size `shard`
/// (the sharding degree of the shaded binary tree is `log2(m/shard)`).
pub fn num_shards(m: u32, shard: u32) -> u32 {
    assert!(shard > 0 && m % shard == 0, "shard size must divide grid");
    m / shard
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_eq1_examples() {
        assert_eq!(slicing_plan(8), vec![1, 2, 4, 8]);
        assert_eq!(slicing_plan(7), vec![7]);
        assert_eq!(slicing_plan(12), vec![3, 6, 12]);
        assert_eq!(slicing_plan(1), vec![1]);
    }

    #[test]
    fn plan_entries_divide_grid() {
        for m in 1..=512 {
            let plan = slicing_plan(m);
            assert_eq!(*plan.last().unwrap(), m);
            for s in plan {
                assert_eq!(m % s, 0);
            }
        }
    }

    #[test]
    fn plan_is_ascending_dichotomy() {
        for m in 1..=256 {
            let plan = slicing_plan(m);
            for w in plan.windows(2) {
                assert_eq!(w[1], w[0] * 2);
            }
        }
    }

    #[test]
    fn shard_count() {
        assert_eq!(num_shards(8, 2), 4);
        assert_eq!(num_shards(12, 12), 1);
    }

    #[test]
    #[should_panic]
    fn nondividing_shard_rejected() {
        num_shards(8, 3);
    }
}
