//! Offline elastic-kernel generation (paper §6).
//!
//! * [`grid`] — elastic grid: dichotomy slicing plan `S(K)` (Eq. 1).
//! * [`block`] — elastic block: persistent-thread sizes (§6.1).
//! * [`candidate`] — one (N_blk_be, S_blk_be) schedule and its shard
//!   launches.
//! * [`shrink`] — Eq. 2 constraints + WIScore (Eq. 4) + OScore (Eq. 5)
//!   design-space shrinking, top-20% keep (§6.3).
//! * [`transformer`] — source-to-source transform metadata and the
//!   computational-consistency verifier (§6.4).

pub mod block;
pub mod candidate;
pub mod grid;
pub mod shrink;
pub mod transformer;


pub use candidate::Candidate;
pub use shrink::{CriticalProfile, ShrinkConfig, ShrunkSpace};

use crate::gpu::kernel::KernelDesc;
use crate::gpu::spec::GpuSpec;

/// A kernel together with its offline-generated elastic candidates — the
/// artifact Miriam's offline phase hands to the runtime coordinator.
#[derive(Debug, Clone)]
pub struct ElasticKernel {
    /// The original (untransformed) kernel.
    pub kernel: KernelDesc,
    /// Shrunk candidate set, best (highest WIScore*OScore) first.
    pub candidates: Vec<Candidate>,
}

impl ElasticKernel {
    /// Run the offline generator for one kernel against representative
    /// critical profiles.
    pub fn generate(kernel: KernelDesc, crits: &[CriticalProfile],
                    spec: &GpuSpec, cfg: &ShrinkConfig) -> Self {
        let shrunk = shrink::shrink_design_space(&kernel, crits, spec, cfg);
        let mut candidates = shrunk.kept;
        // Always keep the identity schedule as a fallback: when no critical
        // kernel is resident the coordinator launches the original geometry.
        let identity = Candidate {
            n_blocks: kernel.grid,
            block_threads: kernel.block_threads,
        };
        if !candidates.contains(&identity) {
            candidates.push(identity);
        }
        ElasticKernel { kernel, candidates }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_includes_identity_fallback() {
        let spec = GpuSpec::rtx2060();
        let k = KernelDesc {
            name: "t".into(),
            grid: 64,
            block_threads: 256,
            smem_per_block: 0,
            regs_per_thread: 32,
            flops: 1e7,
            bytes: 1e5,
        };
        let crits = [CriticalProfile { n_blk_rt: 45, s_blk_rt: 512 }];
        let ek = ElasticKernel::generate(k.clone(), &crits, &spec,
                                         &ShrinkConfig::default());
        assert!(ek.candidates.iter().any(|c| c.n_blocks == k.grid
            && c.block_threads == k.block_threads));
        assert!(!ek.candidates.is_empty());
    }
}
