//! The source-to-source kernel transformer, descriptor side (paper §6.4).
//!
//! On real CUDA, Miriam rewrites kernel source so grid/block sizes become
//! free knobs: physical thread identities (`blockIdx`, `threadIdx`) are
//! replaced by *logical* equivalents computed from a global thread
//! identifier, so any physical geometry covers the same logical iteration
//! space. Our compute path realizes the same transform in Pallas
//! (`python/compile/kernels/elastic_matmul.py::matmul_persistent`); this
//! module is the scheduling-side twin: it constructs the logical→physical
//! remapping for an elastic shard and *proves* (by exhaustive check in
//! tests, and a verifier callable from proptests) that the remap is a
//! partition of the original work — the paper's computational-consistency
//! guarantee.

use crate::gpu::kernel::KernelDesc;

/// The logical→physical mapping of one elastic shard.
///
/// Logical space: blocks `[logical_start, logical_start+logical_blocks)` of
/// the original kernel, each with `logical_threads` logical threads.
/// Physical space: `phys_blocks` blocks of `phys_threads` persistent
/// threads. Assignment is grid-strided in both dimensions, mirroring the
/// generated CUDA/Pallas code.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ElasticMapping {
    /// First logical block this shard covers.
    pub logical_start: u32,
    /// Logical blocks this shard covers.
    pub logical_blocks: u32,
    /// Logical threads per logical block (the original block size).
    pub logical_threads: u32,
    /// Physical blocks dispatched.
    pub phys_blocks: u32,
    /// Persistent threads per physical block.
    pub phys_threads: u32,
}

impl ElasticMapping {
    /// Build the mapping for shard `idx` when a kernel is elasticized to
    /// shards of `n_blocks` physical blocks x `block_threads` threads.
    ///
    /// Physical blocks equal logical blocks per shard (the elastic-grid
    /// transform slices, it does not merge); the persistent-thread N:1
    /// mapping happens inside the block (threads).
    pub fn for_shard(kernel: &KernelDesc, n_blocks: u32, block_threads: u32,
                     idx: u32) -> Self {
        let start = idx * n_blocks;
        assert!(start < kernel.grid, "shard start beyond grid");
        let blocks = n_blocks.min(kernel.grid - start);
        ElasticMapping {
            logical_start: start,
            logical_blocks: blocks,
            logical_threads: kernel.block_threads,
            phys_blocks: blocks,
            phys_threads: block_threads.min(kernel.block_threads),
        }
    }

    /// Logical (block, thread) pairs owned by physical (pb, pt).
    /// Grid-stride within the block: pt covers logical threads
    /// pt, pt+phys_threads, ... (the N:1 persistent mapping).
    pub fn assignments(&self, pb: u32, pt: u32) -> Vec<(u32, u32)> {
        assert!(pb < self.phys_blocks && pt < self.phys_threads);
        let lb = self.logical_start + pb; // 1:1 at block granularity
        (pt..self.logical_threads)
            .step_by(self.phys_threads as usize)
            .map(move |lt| (lb, lt))
            .collect()
    }

    /// Verify the mapping covers every logical (block, thread) of the
    /// shard exactly once. This is the §6.4 consistency theorem for the
    /// descriptor side; the Pallas tests verify it for real numerics.
    pub fn covers_exactly_once(&self) -> bool {
        let total = (self.logical_blocks as usize)
            * (self.logical_threads as usize);
        let mut seen = vec![false; total];
        for pb in 0..self.phys_blocks {
            for pt in 0..self.phys_threads {
                for (lb, lt) in self.assignments(pb, pt) {
                    let rel = (lb - self.logical_start) as usize;
                    let i = rel * self.logical_threads as usize + lt as usize;
                    if seen[i] {
                        return false; // duplicated work
                    }
                    seen[i] = true;
                }
            }
        }
        seen.into_iter().all(|s| s)
    }

    /// The persistence factor N of the N:1 thread mapping.
    pub fn persistence(&self) -> u32 {
        self.logical_threads.div_ceil(self.phys_threads)
    }
}

/// Build and verify all shard mappings for an elastic configuration;
/// returns the mappings or an error description. This is what the offline
/// generator runs per candidate — rejecting any transform that would break
/// computational consistency (none can, by construction, but the check is
/// cheap and guards future edits).
pub fn transform(kernel: &KernelDesc, n_blocks: u32, block_threads: u32)
                 -> Result<Vec<ElasticMapping>, String> {
    if n_blocks == 0 || block_threads == 0 {
        return Err("elastic geometry must be positive".into());
    }
    let shards = kernel.grid.div_ceil(n_blocks);
    let maps: Vec<ElasticMapping> = (0..shards)
        .map(|i| ElasticMapping::for_shard(kernel, n_blocks, block_threads, i))
        .collect();
    // Shards must partition the kernel's logical blocks.
    let covered: u32 = maps.iter().map(|m| m.logical_blocks).sum();
    if covered != kernel.grid {
        return Err(format!("shards cover {covered} of {} blocks", kernel.grid));
    }
    for (i, m) in maps.iter().enumerate() {
        if !m.covers_exactly_once() {
            return Err(format!("shard {i} breaks thread-level consistency"));
        }
    }
    Ok(maps)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kernel(grid: u32, threads: u32) -> KernelDesc {
        KernelDesc {
            name: "t/k".into(),
            grid,
            block_threads: threads,
            smem_per_block: 0,
            regs_per_thread: 16,
            flops: 1e6,
            bytes: 1e4,
        }
    }

    #[test]
    fn identity_mapping_covers() {
        let k = kernel(8, 64);
        let maps = transform(&k, 8, 64).unwrap();
        assert_eq!(maps.len(), 1);
        assert_eq!(maps[0].persistence(), 1);
    }

    #[test]
    fn persistent_threads_cover() {
        let k = kernel(8, 64);
        for bt in [1, 3, 16, 32, 63, 64] {
            let maps = transform(&k, 4, bt).unwrap();
            assert_eq!(maps.len(), 2);
            for m in &maps {
                assert!(m.covers_exactly_once(), "bt={bt}");
            }
        }
    }

    #[test]
    fn ragged_grid_cover() {
        let k = kernel(13, 96);
        let maps = transform(&k, 4, 32).unwrap();
        assert_eq!(maps.len(), 4); // 4+4+4+1
        assert_eq!(maps[3].logical_blocks, 1);
        for m in maps {
            assert!(m.covers_exactly_once());
        }
    }

    #[test]
    fn persistence_factor() {
        let k = kernel(4, 100);
        let maps = transform(&k, 4, 32).unwrap();
        assert_eq!(maps[0].persistence(), 4); // ceil(100/32)
    }

    #[test]
    fn zero_geometry_rejected() {
        let k = kernel(4, 32);
        assert!(transform(&k, 0, 32).is_err());
        assert!(transform(&k, 4, 0).is_err());
    }

    #[test]
    fn assignment_strides_are_disjoint_across_threads() {
        let m = ElasticMapping {
            logical_start: 0,
            logical_blocks: 1,
            logical_threads: 10,
            phys_blocks: 1,
            phys_threads: 3,
        };
        let a0 = m.assignments(0, 0);
        let a1 = m.assignments(0, 1);
        assert_eq!(a0, vec![(0, 0), (0, 3), (0, 6), (0, 9)]);
        assert_eq!(a1, vec![(0, 1), (0, 4), (0, 7)]);
        assert!(m.covers_exactly_once());
    }
}
