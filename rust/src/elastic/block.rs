//! Elastic block: persistent-thread block sizing (paper §6.1).
//!
//! The elastic block shrinks a kernel's resident thread count per block by
//! switching from the default 1:1 logical-to-physical thread mapping to an
//! N:1 mapping (persistent threads, Gupta et al. [10]). Admissible sizes
//! range from one warp up to the original block size, in warp multiples —
//! sub-warp blocks waste issue slots on real hardware, so they are pruned
//! here the same way §6.3 prunes definitely-slow cases.

/// Admissible elastic block sizes for an original block of
/// `original_threads`, on hardware with `warp_size`-wide warps.
/// Descending order (original size first — the "no transformation" point).
pub fn block_size_options(original_threads: u32, warp_size: u32) -> Vec<u32> {
    assert!(original_threads > 0);
    if original_threads <= warp_size {
        return vec![original_threads];
    }
    let mut sizes = Vec::new();
    let mut s = original_threads - original_threads % warp_size;
    if original_threads % warp_size != 0 {
        sizes.push(original_threads); // ragged original stays admissible
    }
    while s >= warp_size {
        sizes.push(s);
        s -= warp_size;
    }
    sizes
}

/// Number of logical threads each persistent physical thread covers when an
/// original `logical` thread count runs on `physical` threads (the N in the
/// N:1 mapping). Ceiling division: the tail round is partially masked.
pub fn persistence_factor(logical: u32, physical: u32) -> u32 {
    assert!(physical > 0);
    logical.div_ceil(physical)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn options_are_warp_multiples_descending() {
        let opts = block_size_options(256, 32);
        assert_eq!(opts.first(), Some(&256));
        assert_eq!(opts.last(), Some(&32));
        for w in opts.windows(2) {
            assert!(w[0] > w[1]);
            assert_eq!(w[1] % 32, 0);
        }
        assert_eq!(opts.len(), 8);
    }

    #[test]
    fn small_blocks_keep_original_only() {
        assert_eq!(block_size_options(17, 32), vec![17]);
        assert_eq!(block_size_options(32, 32), vec![32]);
    }

    #[test]
    fn ragged_original_included() {
        let opts = block_size_options(100, 32);
        assert!(opts.contains(&100));
        assert!(opts.contains(&96));
        assert!(opts.contains(&32));
    }

    #[test]
    fn persistence() {
        assert_eq!(persistence_factor(256, 256), 1);
        assert_eq!(persistence_factor(256, 64), 4);
        assert_eq!(persistence_factor(100, 32), 4); // ceil(100/32)
        assert_eq!(persistence_factor(1, 32), 1);
    }
}
