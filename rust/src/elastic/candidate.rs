//! Elastic-kernel candidates: one point of the (elastic grid x elastic
//! block) design space — a "schedule" in the paper's §6.3 terminology.


use crate::gpu::kernel::{KernelDesc, LaunchConfig};

/// One elastic implementation pattern of a kernel: dispatch shards of
/// `n_blocks` thread blocks (`N_blk_be`), each block running
/// `block_threads` persistent threads (`S_blk_be`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Candidate {
    /// Thread blocks per dispatched shard (`N_blk_be`, Table 1).
    pub n_blocks: u32,
    /// Threads per block (`S_blk_be`, Table 1).
    pub block_threads: u32,
}

impl Candidate {
    /// Number of shard launches needed to cover `kernel` at this candidate.
    pub fn num_shards(&self, kernel: &KernelDesc) -> u32 {
        kernel.grid.div_ceil(self.n_blocks)
    }

    /// The launch config of shard `idx` (0-based). The final shard may
    /// carry fewer logical blocks; work (flops/bytes) is the covered
    /// fraction of the kernel's totals — the persistent-thread transform
    /// keeps per-logical-block work invariant while the physical geometry
    /// shrinks (§6.1/§6.4).
    pub fn shard_launch(&self, kernel: &KernelDesc, idx: u32) -> LaunchConfig {
        let total = self.num_shards(kernel);
        assert!(idx < total, "shard {idx} out of {total}");
        let start = idx * self.n_blocks;
        let blocks = self.n_blocks.min(kernel.grid - start);
        let frac = blocks as f64 / kernel.grid as f64;
        LaunchConfig {
            name: format!("{}#s{}/{}", kernel.name, idx, total),
            grid: blocks,
            block_threads: self.block_threads.min(kernel.block_threads),
            // Elastic transform never increases smem (§6.1): same per-block
            // footprint, or smaller when fewer threads need fewer buffers.
            smem_per_block: scale_smem(kernel, self.block_threads),
            regs_per_thread: kernel.regs_per_thread,
            flops: kernel.flops * frac,
            bytes: kernel.bytes * frac,
        }
    }

    /// All shard launches covering the kernel, in dispatch order.
    pub fn launches(&self, kernel: &KernelDesc) -> Vec<LaunchConfig> {
        (0..self.num_shards(kernel))
            .map(|i| self.shard_launch(kernel, i))
            .collect()
    }
}

/// Shared memory of an elastic block: proportional to the thread ratio but
/// never above the original (the §6.1 guarantee "equal to or less").
fn scale_smem(kernel: &KernelDesc, block_threads: u32) -> u32 {
    if kernel.block_threads == 0 {
        return kernel.smem_per_block;
    }
    let ratio = block_threads as f64 / kernel.block_threads as f64;
    ((kernel.smem_per_block as f64 * ratio.min(1.0)).ceil()) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kernel() -> KernelDesc {
        KernelDesc {
            name: "m/conv1".into(),
            grid: 64,
            block_threads: 256,
            smem_per_block: 8192,
            regs_per_thread: 32,
            flops: 6.4e7,
            bytes: 1.6e6,
        }
    }

    #[test]
    fn shards_cover_all_work_exactly() {
        let k = kernel();
        for c in [
            Candidate { n_blocks: 64, block_threads: 256 },
            Candidate { n_blocks: 16, block_threads: 128 },
            Candidate { n_blocks: 7, block_threads: 32 }, // ragged tail
        ] {
            let launches = c.launches(&k);
            let blocks: u32 = launches.iter().map(|l| l.grid).sum();
            let flops: f64 = launches.iter().map(|l| l.flops).sum();
            let bytes: f64 = launches.iter().map(|l| l.bytes).sum();
            assert_eq!(blocks, k.grid, "{c:?}");
            assert!((flops - k.flops).abs() < 1e-6 * k.flops, "{c:?}");
            assert!((bytes - k.bytes).abs() < 1e-6 * k.bytes, "{c:?}");
        }
    }

    #[test]
    fn identity_candidate_is_one_launch() {
        let k = kernel();
        let c = Candidate { n_blocks: k.grid, block_threads: k.block_threads };
        let launches = c.launches(&k);
        assert_eq!(launches.len(), 1);
        assert_eq!(launches[0].grid, k.grid);
        assert_eq!(launches[0].block_threads, k.block_threads);
        assert_eq!(launches[0].smem_per_block, k.smem_per_block);
    }

    #[test]
    fn smem_never_grows() {
        let k = kernel();
        for bt in [32, 64, 128, 256] {
            let c = Candidate { n_blocks: 8, block_threads: bt };
            for l in c.launches(&k) {
                assert!(l.smem_per_block <= k.smem_per_block);
            }
        }
    }

    #[test]
    fn ragged_final_shard() {
        let k = kernel(); // 64 blocks
        let c = Candidate { n_blocks: 48, block_threads: 256 };
        let launches = c.launches(&k);
        assert_eq!(launches.len(), 2);
        assert_eq!(launches[0].grid, 48);
        assert_eq!(launches[1].grid, 16);
    }

    #[test]
    fn block_threads_never_exceed_original() {
        let k = kernel();
        let c = Candidate { n_blocks: 8, block_threads: 1024 };
        assert_eq!(c.shard_launch(&k, 0).block_threads, k.block_threads);
    }
}
