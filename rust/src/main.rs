//! `miriam` CLI — simulate workloads, regenerate paper figures, run
//! inference through the PJRT runtime.
//!
//! Subcommands:
//!   simulate   --platform rtx2060 --workload A --schedulers all --duration 1.0
//!   infer      --model cifarnet [--artifacts artifacts]
//!   artifacts  [--artifacts artifacts]

use anyhow::{anyhow, Result};

use miriam::config::cli::Args;
use miriam::config::RunConfig;
use miriam::coordinator::{self, driver};
use miriam::gpu::spec::GpuSpec;
use miriam::runtime::Manifest;
use miriam::workloads::{lgsvl, mdtb};

const USAGE: &str = "\
miriam — elastic-kernel multi-DNN coordination on a simulated edge GPU

USAGE:
  miriam simulate [--platform rtx2060|xavier|tx2] [--workload A|B|C|D|lgsvl]
                  [--schedulers sequential,multistream,ib,miriam]
                  [--duration SECONDS]
  miriam infer --model NAME [--artifacts DIR]
  miriam artifacts [--artifacts DIR]
";

fn build_workload(name: &str, duration_us: f64) -> Result<mdtb::Workload> {
    if name.eq_ignore_ascii_case("lgsvl") {
        return Ok(lgsvl::workload(duration_us));
    }
    mdtb::by_name(name, duration_us)
        .map(|w| w.build())
        .ok_or_else(|| anyhow!("unknown workload {name}"))
}

fn simulate(args: &Args) -> Result<()> {
    let platform = args.get("platform", "rtx2060");
    let workload = args.get("workload", "A");
    let schedulers = args.get("schedulers", "sequential,multistream,ib,miriam");
    let duration = args.get_f64("duration", 1.0).map_err(|e| anyhow!(e))?;

    let cfg = RunConfig {
        platform: platform.into(),
        workload: workload.into(),
        schedulers: schedulers.split(',').map(|s| s.trim().to_string()).collect(),
        duration_s: duration,
    };
    cfg.validate().map_err(|e| anyhow!(e))?;
    let spec = GpuSpec::by_name(platform).unwrap();
    let wl = build_workload(workload, duration * 1e6)?;

    println!("# workload {} on {} ({} SMs), {duration}s simulated",
             wl.name, spec.name, spec.num_sms);
    println!("{:<12} {:>10} {:>10} {:>10} {:>12} {:>8} {:>8}",
             "scheduler", "crit p50", "crit p99", "crit mean",
             "throughput", "occup", "norm/s");
    println!("{:<12} {:>10} {:>10} {:>10} {:>12} {:>8} {:>8}",
             "", "(ms)", "(ms)", "(ms)", "(req/s)", "", "");
    for name in &cfg.schedulers {
        let mut sched = coordinator::scheduler_for(name, &wl)
            .ok_or_else(|| anyhow!("unknown scheduler {name}"))?;
        let stats = driver::run(spec.clone(), &wl, sched.as_mut());
        println!("{:<12} {:>10.2} {:>10.2} {:>10.2} {:>12.1} {:>8.3} {:>8.1}",
                 name,
                 stats.critical_latency_quantile_us(0.5) / 1e3,
                 stats.critical_latency_p99_us() / 1e3,
                 stats.critical_latency_mean_us() / 1e3,
                 stats.throughput_rps(),
                 stats.achieved_occupancy,
                 stats.completed_normal() as f64 / (stats.span_us / 1e6));
    }
    Ok(())
}

fn infer(args: &Args) -> Result<()> {
    use miriam::runtime::artifacts::npy_rand;
    let model = args
        .flags
        .get("model")
        .ok_or_else(|| anyhow!("--model is required"))?
        .clone();
    let artifacts = args.get("artifacts", "artifacts");
    let manifest = Manifest::load(artifacts)?;
    let entry = manifest.entry(&model)?.clone();
    let mut rt = miriam::runtime::Runtime::new(manifest)?;
    println!("platform: {}", rt.platform());
    let m = rt.load(&model)?;
    let n: usize = m.input_shapes[0].iter().product();
    let seed = entry.golden.as_ref().map(|g| g.input_seed).unwrap_or(42);
    let input = npy_rand::randn(seed as u32, n);
    let t0 = std::time::Instant::now();
    let out = m.run_f32(&[input])?;
    println!("{model}: output {:?} in {:.2} ms", &out[..out.len().min(10)],
             t0.elapsed().as_secs_f64() * 1e3);
    if let Some(g) = &entry.golden {
        let max_err = out
            .iter()
            .zip(&g.output)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("golden max abs err: {max_err:.3e} {}",
                 if max_err < 1e-3 { "OK" } else { "MISMATCH" });
        if max_err >= 1e-3 {
            return Err(anyhow!("golden mismatch"));
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env().map_err(|e| anyhow!(e))?;
    match args.positional.first().map(String::as_str) {
        Some("simulate") => simulate(&args),
        Some("infer") => infer(&args),
        Some("artifacts") => {
            let m = Manifest::load(args.get("artifacts", "artifacts"))?;
            for e in &m.artifacts {
                println!("{:<16} kind={:<14} file={}", e.name, e.kind,
                         e.file.as_deref().unwrap_or("-"));
            }
            Ok(())
        }
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}
