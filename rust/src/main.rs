//! `miriam` CLI — simulate workloads, regenerate paper figures, run
//! inference through the PJRT runtime, drive the scenario harness.
//!
//! Subcommands:
//!   simulate   --platform rtx2060 --workload A --schedulers all --duration 1.0
//!   scenarios  [--list] [--scenario NAME|all] [--gen N --seed S]
//!              [--trace-out FILE] [--record-golden DIR]
//!   sweep      [--threads N] [--seeds N] [--scenario all|names] — parallel
//!              deterministic scenario×scheduler×seed grid, writes
//!              BENCH_sweep.json (ISSUE 3)
//!   serve-sim  [--scenario all|names] [--policy none,token-bucket,
//!              deadline-feasible] [--seed N] — online admission-controlled
//!              serving loop, writes BENCH_serve.json (ISSUE 4)
//!   fleet-sim  [--devices rtx2060,xavier,tx2] [--router all|names]
//!              [--policy none] [--seed N] [--threads N] — heterogeneous
//!              multi-GPU fleet serving, writes BENCH_fleet.json (ISSUE 5);
//!              [--chaos DSL|--storm all|names] [--standby presets] add
//!              deterministic failure injection and the reactive
//!              autoscaler; --storm runs the resilience grid and writes
//!              BENCH_resilience.json (ISSUE 6);
//!              [--faults DSL|--fault-storm all|names] runs the
//!              request-level fault-injection grid against the
//!              self-healing layer (retries, hedging, cancellation,
//!              breakers, brownout) and writes BENCH_faults.json
//!              (ISSUE 8); [--isolation 70/30,70/30+spill] attaches
//!              hard-SM-split vs elasticity comparison rows (ISSUE 9)
//!   scale-sim  [--tenants 1000,10000,100000] [--duration SECONDS]
//!              [--threads N] — tiered-tenant scale grid over lazy arrival
//!              streams + streaming quantiles, writes BENCH_scale.json
//!              (ISSUE 7)
//!   gen-sim    [--scenario all|names] [--policy none,token-bucket,
//!              deadline-feasible] [--seed N] [--threads N]
//!              [--batch-window-ms W] — autoregressive prefill/decode
//!              serving grid with KV-cache pressure and token-level SLOs,
//!              plus solo/sequential/continuous-batching comparison rows,
//!              writes BENCH_gen.json (ISSUE 10)
//!   infer      --model cifarnet [--artifacts artifacts]
//!   artifacts  [--artifacts artifacts]

use anyhow::{anyhow, Result};

use miriam::config::cli::Args;
use miriam::config::RunConfig;
use miriam::coordinator::admission::{AdmissionConfig, AdmissionPolicy};
use miriam::coordinator::{self, driver, sweep};
use miriam::fleet;
use miriam::gpu::spec::GpuSpec;
use miriam::runtime::Manifest;
use miriam::server::{gen, online, scale};
use miriam::workloads::{generation, lgsvl, mdtb, scenario};

const USAGE: &str = "\
miriam — elastic-kernel multi-DNN coordination on a simulated edge GPU

USAGE:
  miriam simulate [--platform rtx2060|xavier|tx2] [--workload A|B|C|D|lgsvl]
                  [--schedulers sequential,multistream,ib,miriam]
                  [--duration SECONDS]
  miriam scenarios [--list] [--platform P] [--duration SECONDS]
                   [--scenario NAME|all] [--gen N] [--seed S]
                   [--schedulers s1,s2,...] [--trace-out FILE]
                   [--record-golden DIR]
  miriam sweep [--platform P] [--duration SECONDS] [--scenario all|n1,n2,...]
               [--schedulers s1,s2,...] [--seeds N] [--threads N]
               [--isolation 70/30,70/30+spill] [--out BENCH_sweep.json]
  miriam serve-sim [--platform P] [--duration SECONDS]
                   [--scenario all|n1,n2,...] [--scheduler miriam]
                   [--policy none,token-bucket,deadline-feasible] [--seed N]
                   [--bucket-cap 16] [--refill-hz 40] [--max-queue-ms 100]
                   [--drain-ways 3] [--backoff-ms 2] [--out BENCH_serve.json]
  miriam fleet-sim [--devices rtx2060,xavier,tx2] [--schedulers miriam|per-dev]
                   [--router all|round-robin,least-outstanding-work,
                    criticality-affinity] [--scenario all|n1,n2,...]
                   [--policy none] [--duration SECONDS] [--seed N]
                   [--threads N] [--bucket-cap 16] [--refill-hz 40]
                   [--max-queue-ms 100] [--drain-ways 3] [--backoff-ms 2]
                   [--chaos \"down:d1@800ms+2s,throttle:d0@1s*0.6+500ms\"
                    | --storm all|none,straggler-storm,rolling-outage,
                      flash-crowd-outage]
                   [--standby preset1,preset2] [--standby-scheduler miriam]
                   [--scale-high-ms 20] [--scale-low-ms 4] [--scale-eval-ms 5]
                   [--scale-cooldown-ms 20]
                   [--faults \"fail:p=0.001,straggle:p=0.01*4x,corrupt:p=0.0005\"
                    | --fault-storm all|none,flaky-launches,straggler-swarm,
                      bitflip-storm,full-fault-storm]
                   [--isolation 70/30,70/30+spill]
                   [--out BENCH_fleet.json|BENCH_resilience.json|
                    BENCH_faults.json]
  miriam scale-sim [--platform P] [--tenants 1000,10000,100000]
                   [--duration SECONDS] [--scheduler miriam] [--threads N]
                   [--out BENCH_scale.json]
  miriam gen-sim [--platform P] [--duration SECONDS]
                 [--scenario all|gen-duo,gen-pressure,gen-storm,gen-diff]
                 [--scheduler miriam]
                 [--policy none,token-bucket,deadline-feasible] [--seed N]
                 [--threads N] [--batch-window-ms W] [--bucket-cap 16]
                 [--refill-hz 40] [--max-queue-ms 100] [--drain-ways 3]
                 [--backoff-ms 2] [--out BENCH_gen.json]
  miriam infer --model NAME [--artifacts DIR]
  miriam artifacts [--artifacts DIR]
";

fn build_workload(name: &str, duration_us: f64) -> Result<mdtb::Workload> {
    if name.eq_ignore_ascii_case("lgsvl") {
        return Ok(lgsvl::workload(duration_us));
    }
    mdtb::by_name(name, duration_us)
        .map(|w| w.build())
        .ok_or_else(|| anyhow!("unknown workload {name}"))
}

/// Resolve `--scenario all|n1,n2,...` for the grid subcommands (`sweep`,
/// `serve-sim`, `fleet-sim`). Named cells resolve against the family,
/// the standalone flash-crowd stress scenario, *and* the MDTB workloads,
/// so any BENCH_*.json cell is reproducible by name here (`all` stays
/// the family alone so committed baselines are unaffected).
fn resolve_scenarios(args: &Args, dur_us: f64)
                     -> Result<Vec<scenario::ScenarioSpec>> {
    let which = args.get("scenario", "all");
    if which.eq_ignore_ascii_case("all") {
        return Ok(scenario::family(dur_us));
    }
    let pool: Vec<_> = scenario::family(dur_us)
        .into_iter()
        .chain(std::iter::once(scenario::flash_crowd(dur_us)))
        .chain(scenario::mdtb_scenarios(dur_us))
        .collect();
    args.get_list("scenario", "")
        .iter()
        .map(|n| {
            pool.iter()
                .find(|s| s.name.eq_ignore_ascii_case(n))
                .cloned()
                .ok_or_else(|| anyhow!("unknown scenario {n}"))
        })
        .collect()
}

/// Parse `--isolation A/B[+spill],...` into validated isolation
/// scheduler names (ISSUE 9). Fail-fast: every split must parse
/// ([`coordinator::IsolationConfig::parse`]) *and* partition every
/// listed device's SM count without starving a class — a long grid must
/// never die mid-run on a split that rounds a share to zero SMs.
/// Returns an empty list when the flag is absent.
fn isolation_schedulers(args: &Args, sm_counts: &[(String, u32)])
                        -> Result<Vec<String>> {
    if !args.has("isolation") {
        return Ok(Vec::new());
    }
    let mut out = Vec::new();
    for split in args.get_list("isolation", "70/30") {
        let cfg = coordinator::IsolationConfig::parse(&split)
            .map_err(|e| anyhow!(e))?;
        for (name, sms) in sm_counts {
            cfg.partition(*sms).map_err(|e| anyhow!("{name}: {e}"))?;
        }
        let n = cfg.scheduler_name();
        if !out.contains(&n) {
            out.push(n);
        }
    }
    if out.is_empty() {
        return Err(anyhow!("--isolation needs at least one split \
                            (e.g. --isolation 70/30,70/30+spill)"));
    }
    Ok(out)
}

/// Parse the admission tunables shared by `serve-sim` and `fleet-sim`
/// (same flags, same defaults, same ms→us scaling).
fn admission_from_args(args: &Args) -> Result<AdmissionConfig> {
    Ok(AdmissionConfig {
        bucket_capacity: args.get_f64("bucket-cap", 16.0)
            .map_err(|e| anyhow!(e))?,
        refill_hz: args.get_f64("refill-hz", 40.0).map_err(|e| anyhow!(e))?,
        max_queue_us: args.get_f64("max-queue-ms", 100.0)
            .map_err(|e| anyhow!(e))?
            * 1e3,
        drain_ways: args.get_f64("drain-ways", 3.0)
            .map_err(|e| anyhow!(e))?,
        shed_backoff_us: args.get_f64("backoff-ms", 2.0)
            .map_err(|e| anyhow!(e))?
            * 1e3,
    })
}

/// Parse the optional `--seed` override shared by the serving
/// subcommands (`None` keeps each scenario's pinned seed).
fn seed_from_args(args: &Args) -> Result<Option<u64>> {
    if args.has("seed") {
        Ok(Some(args.get_u64("seed", 0).map_err(|e| anyhow!(e))?))
    } else {
        Ok(None)
    }
}

fn simulate(args: &Args) -> Result<()> {
    let platform = args.get("platform", "rtx2060");
    let workload = args.get("workload", "A");
    let schedulers = args.get("schedulers", "sequential,multistream,ib,miriam");
    let duration = args.get_f64("duration", 1.0).map_err(|e| anyhow!(e))?;

    let cfg = RunConfig {
        platform: platform.into(),
        workload: workload.into(),
        schedulers: schedulers.split(',').map(|s| s.trim().to_string()).collect(),
        duration_s: duration,
    };
    cfg.validate().map_err(|e| anyhow!(e))?;
    let spec = GpuSpec::by_name(platform).unwrap();
    let wl = build_workload(workload, duration * 1e6)?;

    println!("# workload {} on {} ({} SMs), {duration}s simulated",
             wl.name, spec.name, spec.num_sms);
    println!("{:<12} {:>10} {:>10} {:>10} {:>12} {:>8} {:>8}",
             "scheduler", "crit p50", "crit p99", "crit mean",
             "throughput", "occup", "norm/s");
    println!("{:<12} {:>10} {:>10} {:>10} {:>12} {:>8} {:>8}",
             "", "(ms)", "(ms)", "(ms)", "(req/s)", "", "");
    for name in &cfg.schedulers {
        let mut sched = coordinator::scheduler_for(name, &wl)
            .ok_or_else(|| anyhow!("unknown scheduler {name}"))?;
        let stats = driver::run(spec.clone(), &wl, sched.as_mut());
        println!("{:<12} {:>10.2} {:>10.2} {:>10.2} {:>12.1} {:>8.3} {:>8.1}",
                 name,
                 stats.critical_latency_quantile_us(0.5) / 1e3,
                 stats.critical_latency_p99_us() / 1e3,
                 stats.critical_latency_mean_us() / 1e3,
                 stats.throughput_rps(),
                 stats.achieved_occupancy,
                 stats.completed_normal() as f64 / (stats.span_us / 1e6));
    }
    Ok(())
}

/// The scenario harness: list/run the named scenario family (plus seeded
/// generated scenarios), optionally recording canonical engine traces
/// (`--trace-out` for one cell, `--record-golden` for the pinned
/// conformance subset — see EXPERIMENTS.md §Scenarios).
fn scenarios(args: &Args) -> Result<()> {
    let platform = args.get("platform", "rtx2060");
    let spec = GpuSpec::by_name(platform)
        .ok_or_else(|| anyhow!("unknown platform {platform}"))?;
    let duration = args.get_f64("duration", 0.2).map_err(|e| anyhow!(e))?;
    if duration <= 0.0 {
        return Err(anyhow!("duration must be positive"));
    }
    let dur_us = duration * 1e6;

    if args.has("list") {
        for sc in scenario::family(dur_us) {
            println!("{:<16} {} tenants ({} critical), seed {:#x}",
                     sc.name, sc.tenants(), sc.criticals(), sc.seed);
        }
        return Ok(());
    }

    if let Some(dir) = args.get_opt("record-golden") {
        // Goldens are pinned per cell (platform and duration); recording
        // under any other --platform would poison the conformance anchors.
        if platform != scenario::GOLDEN_PLATFORM {
            return Err(anyhow!(
                "--record-golden is pinned to --platform {} (got {platform})",
                scenario::GOLDEN_PLATFORM));
        }
        let dir = std::path::Path::new(dir);
        for (path, events) in driver::record_golden_traces(dir)? {
            println!("recorded {} ({events} events)", path.display());
        }
        // The per-device anchors (xavier/tx2 cells, ISSUE 5) live in a
        // subdirectory and are recorded by the same invocation so the
        // two golden sets can never desynchronize.
        for (path, events) in driver::record_device_golden_traces(
            &dir.join(scenario::DEVICE_GOLDEN_SUBDIR))?
        {
            println!("recorded {} ({events} events)", path.display());
        }
        // Likewise the generation anchors (ISSUE 10): same invocation,
        // same pinned platform/duration, own subdirectory.
        for (path, events) in gen::record_gen_golden_traces(
            &dir.join(generation::GEN_GOLDEN_SUBDIR))?
        {
            println!("recorded {} ({events} events)", path.display());
        }
        return Ok(());
    }

    let which = args.get("scenario", "all");
    let gen_n = args.get_usize("gen", 0).map_err(|e| anyhow!(e))?;
    let seed = args.get_u64("seed", 0x5CE7).map_err(|e| anyhow!(e))?;
    let mut specs = if which.eq_ignore_ascii_case("all") {
        scenario::family(dur_us)
    } else {
        vec![scenario::by_name(which, dur_us)
            .ok_or_else(|| anyhow!("unknown scenario {which}"))?]
    };
    if gen_n > 0 {
        specs.extend(scenario::ScenarioGen::new(seed, dur_us).take(gen_n));
    }
    let schedulers: Vec<String> = args
        .get("schedulers", "sequential,multistream,ib,miriam")
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let trace_out = args.get_opt("trace-out");
    if trace_out.is_some() && (specs.len() != 1 || schedulers.len() != 1) {
        return Err(anyhow!(
            "--trace-out needs exactly one --scenario and one scheduler"));
    }

    println!("# {} scenario(s) on {} ({} SMs), {duration}s simulated",
             specs.len(), spec.name, spec.num_sms);
    println!("{:<16} {:<12} {:>10} {:>10} {:>8} {:>12} {:>8}",
             "scenario", "scheduler", "crit p50", "crit p99", "miss",
             "throughput", "occup");
    println!("{:<16} {:<12} {:>10} {:>10} {:>8} {:>12} {:>8}",
             "", "", "(ms)", "(ms)", "(crit)", "(req/s)", "");
    for sc in &specs {
        let wl = sc.build();
        for name in &schedulers {
            let mut sched = coordinator::scheduler_for(name, &wl)
                .ok_or_else(|| anyhow!("unknown scheduler {name}"))?;
            let opts = driver::RunOpts {
                reference_rates: false,
                trace: trace_out.is_some(),
            };
            let stats = driver::run_with(spec.clone(), &wl, sched.as_mut(),
                                         opts);
            println!("{:<16} {:<12} {:>10.2} {:>10.2} {:>8} {:>12.1} {:>8.3}",
                     sc.name, name,
                     stats.critical_latency_quantile_us(0.5) / 1e3,
                     stats.critical_latency_p99_us() / 1e3,
                     stats.deadline_misses_critical,
                     stats.throughput_rps(),
                     stats.achieved_occupancy);
            if let Some(path) = trace_out {
                let trace = stats.trace.expect("trace was requested");
                std::fs::write(path, trace.to_canonical_json())?;
                println!("wrote {} ({} events)", path, trace.len());
            }
        }
    }
    Ok(())
}

/// The parallel deterministic sweep (ISSUE 3 tentpole): scenario family ×
/// scheduler set × seed replicas across a worker pool, aggregate report to
/// stdout and `BENCH_sweep.json`. Results are byte-identical for any
/// `--threads`; the default scheduler set includes `miriam-ref` (the
/// retained pre-change coordinator) so the report always carries the
/// coordinator-in-the-loop before/after comparison.
fn sweep_cmd(args: &Args) -> Result<()> {
    let platform = args.get("platform", "rtx2060");
    let duration = args.get_f64("duration", 0.04).map_err(|e| anyhow!(e))?;
    if duration <= 0.0 {
        return Err(anyhow!("duration must be positive"));
    }
    let dur_us = duration * 1e6;
    let scenarios = resolve_scenarios(args, dur_us)?;
    let mut schedulers = args.get_list(
        "schedulers", "sequential,multistream,ib,miriam,miriam-ref");
    // --isolation appends hard-isolation columns to the scheduler grid
    // (ISSUE 9), each split pre-validated against the platform's SM
    // count; the report then carries the isolation-vs-miriam section.
    let gpu = GpuSpec::by_name(platform)
        .ok_or_else(|| anyhow!("unknown platform {platform}"))?;
    for name in isolation_schedulers(
        args, &[(platform.to_string(), gpu.num_sms)])?
    {
        if !schedulers.contains(&name) {
            schedulers.push(name);
        }
    }
    let seeds = args.get_usize("seeds", 8).map_err(|e| anyhow!(e))? as u32;
    let default_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let threads = args
        .get_usize("threads", default_threads)
        .map_err(|e| anyhow!(e))?;
    let out = args.get("out", "BENCH_sweep.json");

    let spec = sweep::SweepSpec {
        platform: platform.into(),
        duration_us: dur_us,
        scenarios,
        schedulers,
        seeds,
        trace: false,
        reference_rates: false,
    };
    let cells = spec.scenarios.len() * spec.schedulers.len() * seeds as usize;
    println!("# sweep: {} scenario(s) x {} scheduler(s) x {} seed(s) = \
              {cells} cells, {duration}s simulated each, {threads} thread(s)",
             spec.scenarios.len(), spec.schedulers.len(), seeds);
    let report = sweep::run_sweep(&spec, threads).map_err(|e| anyhow!(e))?;

    println!("{:<16} {:<12} {:>10} {:>10} {:>8} {:>12} {:>12}",
             "scenario", "scheduler", "crit p50", "crit p99", "miss",
             "throughput", "events/s");
    println!("{:<16} {:<12} {:>10} {:>10} {:>8} {:>12} {:>12}",
             "", "", "(ms)", "(ms)", "(crit)", "(req/s)", "");
    for a in report.aggregates() {
        println!("{:<16} {:<12} {:>10.2} {:>10.2} {:>8} {:>12.1} {:>12.0}",
                 a.scenario, a.scheduler,
                 a.mean_crit_p50_us / 1e3,
                 a.mean_crit_p99_us / 1e3,
                 a.deadline_misses_critical,
                 a.mean_throughput_rps,
                 a.events_per_sec());
    }
    println!("\n{} cells in {:.3}s wall ({} threads), {} events, \
              {:.0} events/s aggregate",
             report.cells.len(), report.wall_s, report.threads,
             report.total_events(), report.events_per_sec());
    let fast = report.events_per_sec_for("miriam");
    let refp = report.events_per_sec_for("miriam-ref");
    if fast > 0.0 && refp > 0.0 {
        println!("coordinator fast path: {:.0} events/s vs {:.0} reference \
                  ({:+.1}%)",
                 fast, refp, (fast / refp - 1.0) * 100.0);
    }
    std::fs::write(out, report.to_json())?;
    println!("wrote {out}");
    Ok(())
}

/// The online admission-controlled serving loop (ISSUE 4 tentpole):
/// scenario arrivals flow through an admission policy into the live
/// coordinator; per-tenant SLO outcomes (admitted/shed/served/missed,
/// p50/p99) go to stdout and `BENCH_serve.json`. Byte-deterministic per
/// seed — the report carries no host timing
/// (`rust/tests/serve_determinism.rs` pins repeat-run equality).
fn serve_sim(args: &Args) -> Result<()> {
    let platform = args.get("platform", "rtx2060");
    let gpu = GpuSpec::by_name(platform)
        .ok_or_else(|| anyhow!("unknown platform {platform}"))?;
    let duration = args.get_f64("duration", 0.2).map_err(|e| anyhow!(e))?;
    if duration <= 0.0 {
        return Err(anyhow!("duration must be positive"));
    }
    let dur_us = duration * 1e6;
    let scenarios = resolve_scenarios(args, dur_us)?;
    let policies = args
        .get_list("policy", "none,token-bucket,deadline-feasible")
        .iter()
        .map(|p| {
            AdmissionPolicy::parse(p)
                .ok_or_else(|| anyhow!("unknown policy {p}"))
        })
        .collect::<Result<Vec<_>>>()?;
    let admission = admission_from_args(args)?;
    let seed = seed_from_args(args)?;
    let opts = online::ServeOpts {
        scheduler: args.get("scheduler", "miriam").to_string(),
        policy: AdmissionPolicy::Open, // per-cell policy comes from the grid
        admission,
        seed,
    };
    let out = args.get("out", "BENCH_serve.json");

    println!("# serve-sim: {} scenario(s) x {} policy(ies) on {} ({} SMs), \
              {duration}s of arrivals each, scheduler {}",
             scenarios.len(), policies.len(), gpu.name, gpu.num_sms,
             opts.scheduler);
    println!("{:<16} {:<18} {:>8} {:>8} {:>6} {:>8} {:>10} {:>10} {:>6} {:>10}",
             "scenario", "policy", "offered", "admit", "shed", "served",
             "crit p50", "crit p99", "miss", "norm/s");
    println!("{:<16} {:<18} {:>8} {:>8} {:>6} {:>8} {:>10} {:>10} {:>6} {:>10}",
             "", "", "", "", "", "", "(ms)", "(ms)", "(crit)", "(req/s)");
    let grid = online::run_serve_grid(&gpu, &scenarios, &policies, &opts)
        .map_err(|e| anyhow!(e))?;
    for c in &grid.cells {
        println!("{:<16} {:<18} {:>8} {:>8} {:>6} {:>8} {:>10.2} {:>10.2} \
                  {:>6} {:>10.1}",
                 c.scenario, c.policy.name(), c.offered(), c.admitted(),
                 c.shed(), c.served(),
                 c.crit_quantile_us(0.5) / 1e3,
                 c.crit_p99_us() / 1e3,
                 c.deadline_misses_critical(),
                 c.normal_throughput_rps());
    }
    std::fs::write(out, grid.to_json())?;
    println!("wrote {out}");
    Ok(())
}

/// Parse the optional reactive-autoscaler tunables: `--standby` arms the
/// scaler with a pool of `GpuSpec` preset names; the watermark/cadence
/// flags mirror the [`fleet::AutoscaleConfig`] defaults (ms on the CLI,
/// simulated µs inside — same scaling as the admission flags).
fn autoscale_from_args(args: &Args) -> Result<Option<fleet::AutoscaleConfig>> {
    if !args.has("standby") {
        return Ok(None);
    }
    Ok(Some(fleet::AutoscaleConfig {
        pool: args.get_list("standby", ""),
        scheduler: args.get("standby-scheduler", "miriam").to_string(),
        high_watermark_us: args.get_f64("scale-high-ms", 20.0)
            .map_err(|e| anyhow!(e))?
            * 1e3,
        low_watermark_us: args.get_f64("scale-low-ms", 4.0)
            .map_err(|e| anyhow!(e))?
            * 1e3,
        eval_period_us: args.get_f64("scale-eval-ms", 5.0)
            .map_err(|e| anyhow!(e))?
            * 1e3,
        cooldown_us: args.get_f64("scale-cooldown-ms", 20.0)
            .map_err(|e| anyhow!(e))?
            * 1e3,
    }))
}

/// The `fleet-sim --storm` path (ISSUE 6): the scenarios × storms ×
/// routers resilience grid, stdout table plus `BENCH_resilience.json`.
/// Every storm column is the same named weather rescaled to its
/// scenario's window; `recovery` is the slowest outage-to-heal gap in a
/// cell (`-` when the storm killed no device).
#[allow(clippy::too_many_arguments)]
fn resilience_sim(
    args: &Args,
    spec: &fleet::FleetSpec,
    scenarios: &[scenario::ScenarioSpec],
    storms: &[String],
    routers: &[String],
    opts: &fleet::FleetOpts,
    threads: usize,
    duration: f64,
) -> Result<()> {
    let out = args.get("out", "BENCH_resilience.json");
    let standby = opts.autoscale.as_ref().map_or(0, |a| a.pool.len());
    println!("# fleet-sim resilience: {} scenario(s) x {} storm(s) x {} \
              router(s) on {} device(s) (+{standby} standby), {duration}s \
              of arrivals each, policy {}, {threads} thread(s)",
             scenarios.len(), storms.len(), routers.len(),
             spec.devices.len(), opts.policy.name());
    let grid = fleet::run_resilience_grid(spec, scenarios, storms, routers,
                                          opts, threads)
        .map_err(|e| anyhow!(e))?;
    println!("{:<16} {:<20} {:<22} {:>8} {:>8} {:>6} {:>10} {:>10}",
             "scenario", "storm", "router", "served", "requeues", "lost",
             "crit p99", "recovery");
    println!("{:<16} {:<20} {:<22} {:>8} {:>8} {:>6} {:>10} {:>10}",
             "", "", "", "", "", "", "(ms)", "(ms)");
    for c in &grid.cells {
        let recovery = if c.recovery_us.is_nan() {
            "-".to_string()
        } else {
            format!("{:.2}", c.recovery_us / 1e3)
        };
        println!("{:<16} {:<20} {:<22} {:>8} {:>8} {:>6} {:>10.2} {:>10}",
                 c.scenario, c.chaos, c.router, c.served(), c.requeues(),
                 c.lost(), c.crit_p99_us() / 1e3, recovery);
    }
    std::fs::write(out, grid.to_json())?;
    println!("wrote {out}");
    Ok(())
}

/// The `fleet-sim --faults`/`--fault-storm` path (ISSUE 8): the
/// scenarios × fault-scripts × routers self-healing grid, stdout table
/// plus `BENCH_faults.json`. A fault-free `none` column is always in
/// the grid so every cell carries a critical-p99 degradation ratio
/// against calm weather.
#[allow(clippy::too_many_arguments)]
fn faults_sim(
    args: &Args,
    spec: &fleet::FleetSpec,
    scenarios: &[scenario::ScenarioSpec],
    fault_specs: &[fleet::FaultSpec],
    routers: &[String],
    opts: &fleet::FleetOpts,
    threads: usize,
    duration: f64,
) -> Result<()> {
    let out = args.get("out", "BENCH_faults.json");
    println!("# fleet-sim faults: {} scenario(s) x {} fault script(s) x {} \
              router(s) on {} device(s), {duration}s of arrivals each, \
              policy {}, {threads} thread(s)",
             scenarios.len(), fault_specs.len(), routers.len(),
             spec.devices.len(), opts.policy.name());
    let grid = fleet::run_faults_grid(spec, scenarios, fault_specs, routers,
                                      opts, threads)
        .map_err(|e| anyhow!(e))?;
    println!("{:<16} {:<18} {:<22} {:>8} {:>7} {:>6} {:>5} {:>7} {:>6} \
              {:>10}",
             "scenario", "faults", "router", "served", "retries", "hedges",
             "wins", "cancel", "trips", "crit p99");
    println!("{:<16} {:<18} {:<22} {:>8} {:>7} {:>6} {:>5} {:>7} {:>6} \
              {:>10}",
             "", "", "", "", "", "", "", "", "", "(ms)");
    for c in &grid.cells {
        println!("{:<16} {:<18} {:<22} {:>8} {:>7} {:>6} {:>5} {:>7} {:>6} \
                  {:>10.2}",
                 c.scenario, c.fault_script, c.router, c.served(),
                 c.retries(), c.hedges(), c.hedge_wins(), c.cancelled(),
                 c.breaker_trips(), c.crit_p99_us() / 1e3);
    }
    std::fs::write(out, grid.to_json())?;
    println!("wrote {out}");
    Ok(())
}

/// Heterogeneous multi-GPU fleet serving (ISSUE 5 tentpole): scenario
/// arrivals pass through one fleet-wide admission policy, each admitted
/// request is placed on a device by the chosen router, and per-device /
/// per-tenant / fleet-level outcomes go to stdout and `BENCH_fleet.json`.
/// Byte-deterministic per (seed, devices, router) and across `--threads`
/// (`rust/tests/fleet_determinism.rs` pins both).
fn fleet_sim(args: &Args) -> Result<()> {
    let devices = args.get_list("devices", "rtx2060,xavier,tx2");
    let schedulers = args.get_list("schedulers", "miriam");
    let spec =
        fleet::FleetSpec::parse(&devices, &schedulers).map_err(|e| anyhow!(e))?;
    let duration = args.get_f64("duration", 0.2).map_err(|e| anyhow!(e))?;
    if duration <= 0.0 {
        return Err(anyhow!("duration must be positive"));
    }
    let dur_us = duration * 1e6;
    let scenarios = resolve_scenarios(args, dur_us)?;
    let router_arg = args.get("router", "all");
    let routers: Vec<String> = if router_arg.eq_ignore_ascii_case("all") {
        fleet::ROUTERS.iter().map(|r| r.to_string()).collect()
    } else {
        args.get_list("router", "")
    };
    // Fail fast on router typos so a long grid never dies mid-run with a
    // per-cell error (the grid runners re-check; this is the CLI gate).
    for r in &routers {
        if fleet::router_for(r, spec.devices.len()).is_none() {
            return Err(anyhow!("unknown router {r} (available: {})",
                               fleet::ROUTERS.join(", ")));
        }
    }
    let policy_name = args.get("policy", "none");
    let policy = AdmissionPolicy::parse(policy_name)
        .ok_or_else(|| anyhow!("unknown policy {policy_name}"))?;
    let default_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let threads = args
        .get_usize("threads", default_threads)
        .map_err(|e| anyhow!(e))?;
    let autoscale = autoscale_from_args(args)?;
    if args.has("chaos") && args.has("storm") {
        return Err(anyhow!(
            "--chaos and --storm are mutually exclusive: --chaos scripts \
             one event list, --storm sweeps the named presets"));
    }
    let wants_faults = args.has("faults") || args.has("fault-storm");
    if wants_faults && (args.has("chaos") || args.has("storm")) {
        return Err(anyhow!(
            "--faults/--fault-storm and --chaos/--storm are mutually \
             exclusive: request-level fault injection and device-level \
             chaos run as separate grids (compose them through the \
             library's FleetOpts when you need both)"));
    }
    if args.has("faults") && args.has("fault-storm") {
        return Err(anyhow!(
            "--faults and --fault-storm are mutually exclusive: --faults \
             scripts one fault model, --fault-storm sweeps the named \
             presets"));
    }
    // --isolation re-runs the grid with every device on each split and
    // attaches comparison rows (ISSUE 9); validated fail-fast against
    // every device's SM count.
    let iso_splits = isolation_schedulers(
        args,
        &spec
            .devices
            .iter()
            .map(|d| (d.name.clone(), d.gpu.num_sms))
            .collect::<Vec<_>>(),
    )?;
    if !iso_splits.is_empty()
        && (wants_faults || args.has("chaos") || args.has("storm"))
    {
        return Err(anyhow!(
            "--isolation and --chaos/--storm/--faults/--fault-storm are \
             mutually exclusive: the isolation comparison runs on calm \
             weather (compose them through the library's FleetOpts when \
             you need both)"));
    }
    let chaos = match args.get_opt("chaos") {
        Some(dsl) => {
            let c = fleet::ChaosSpec::parse(dsl).map_err(|e| anyhow!(e))?;
            let total = spec.devices.len()
                + autoscale.as_ref().map_or(0, |a| a.pool.len());
            c.validate(total).map_err(|e| anyhow!(e))?;
            c
        }
        None => fleet::ChaosSpec::none(),
    };
    let opts = fleet::FleetOpts {
        router: String::new(), // per-cell router comes from the grid
        policy,
        admission: admission_from_args(args)?,
        seed: seed_from_args(args)?,
        chaos,
        autoscale,
        // The fault path goes through faults_sim, which threads the
        // per-cell specs into the grid runner itself.
        faults: None,
    };
    if wants_faults {
        let mut fault_specs = match args.get_opt("faults") {
            Some(dsl) => {
                let f =
                    fleet::FaultSpec::parse(dsl).map_err(|e| anyhow!(e))?;
                if f.is_inert() {
                    return Err(anyhow!(
                        "--faults `{dsl}` injects nothing; omit the flag \
                         for a fault-free run"));
                }
                vec![f]
            }
            None => fleet::faults::resolve_storms(
                args.get("fault-storm", "all"))
                .map_err(|e| anyhow!(e))?,
        };
        if !fault_specs.iter().any(|f| f.is_inert()) {
            fault_specs.insert(0, fleet::FaultSpec::none());
        }
        return faults_sim(args, &spec, &scenarios, &fault_specs, &routers,
                          &opts, threads, duration);
    }
    if let Some(which) = args.get_opt("storm") {
        let storms: Vec<String> = if which.eq_ignore_ascii_case("all") {
            fleet::STORMS.iter().map(|s| s.to_string()).collect()
        } else {
            args.get_list("storm", "")
        };
        return resilience_sim(args, &spec, &scenarios, &storms, &routers,
                              &opts, threads, duration);
    }
    let out = args.get("out", "BENCH_fleet.json");

    println!("# fleet-sim: {} scenario(s) x {} router(s) on {} device(s) \
              [{}], {duration}s of arrivals each, policy {}, {threads} \
              thread(s)",
             scenarios.len(), routers.len(), spec.devices.len(),
             spec.devices
                 .iter()
                 .map(|d| d.gpu.name.as_str())
                 .collect::<Vec<_>>()
                 .join(","),
             policy.name());
    if !opts.chaos.is_empty() {
        println!("# chaos: {} ({} event(s))", opts.chaos.name,
                 opts.chaos.events.len());
    }
    let mut grid = fleet::run_fleet_grid(&spec, &scenarios, &routers, &opts,
                                         threads)
        .map_err(|e| anyhow!(e))?;
    println!("{:<16} {:<22} {:>8} {:>8} {:>6} {:>8} {:>10} {:>10} {:>6} {:>9}",
             "scenario", "router", "offered", "admit", "shed", "served",
             "crit p50", "crit p99", "miss", "fleet r/s");
    println!("{:<16} {:<22} {:>8} {:>8} {:>6} {:>8} {:>10} {:>10} {:>6} {:>9}",
             "", "", "", "", "", "", "(ms)", "(ms)", "(crit)", "");
    for c in &grid.cells {
        println!("{:<16} {:<22} {:>8} {:>8} {:>6} {:>8} {:>10.2} {:>10.2} \
                  {:>6} {:>9.1}",
                 c.scenario, c.router, c.offered(), c.admitted(), c.shed(),
                 c.served(),
                 c.crit_quantile_us(0.5) / 1e3,
                 c.crit_p99_us() / 1e3,
                 c.deadline_misses_critical(),
                 c.throughput_rps());
    }
    // Per-device placement summary of the first scenario's cells — the
    // quickest read on how each router spread the load.
    if let Some(first) = grid.scenarios.first() {
        println!("\n# placement on {first} (requests routed per device)");
        for r in &grid.routers {
            if let Some(c) = grid.cell(first, r) {
                let split = c
                    .devices
                    .iter()
                    .map(|d| format!("{}={} ({}c)", d.desc.name, d.routed,
                                     d.routed_critical))
                    .collect::<Vec<_>>()
                    .join("  ");
                println!("{r:<22} {split}");
            }
        }
    }
    if !iso_splits.is_empty() {
        let rows = fleet::run_isolation_comparison(
            &spec, &scenarios, &routers, &opts, &iso_splits, &grid, threads)
            .map_err(|e| anyhow!(e))?;
        println!("\n# isolation vs {} (hard SM split, every device)",
                 spec.devices
                     .first()
                     .map(|d| d.scheduler.as_str())
                     .unwrap_or("baseline"));
        println!("{:<22} {:<16} {:<22} {:>10} {:>9} {:>9} {:>9}",
                 "scheduler", "scenario", "router", "crit p99", "p99 x",
                 "fleet r/s", "r/s x");
        for r in &rows {
            println!("{:<22} {:<16} {:<22} {:>10.2} {:>9.3} {:>9.1} {:>9.3}",
                     r.scheduler, r.scenario, r.router,
                     r.crit_p99_us / 1e3,
                     if r.base_crit_p99_us > 0.0 {
                         r.crit_p99_us / r.base_crit_p99_us
                     } else {
                         0.0
                     },
                     r.throughput_rps,
                     if r.base_throughput_rps > 0.0 {
                         r.throughput_rps / r.base_throughput_rps
                     } else {
                         0.0
                     });
        }
        grid.isolation = rows;
    }
    std::fs::write(out, grid.to_json())?;
    println!("wrote {out}");
    Ok(())
}

/// `scale-sim` (ISSUE 7): the tiered-tenant scale grid — lazy arrival
/// streams through the timing wheel, P² latency sketches above the
/// tenant threshold — stdout table plus `BENCH_scale.json`. The JSON is
/// byte-deterministic across `--threads` and repeats; the events/sec
/// column is host-timed and goes to stdout only.
fn scale_sim(args: &Args) -> Result<()> {
    let platform = args.get("platform", "rtx2060");
    let gpu = GpuSpec::by_name(platform)
        .ok_or_else(|| anyhow!("unknown platform {platform}"))?;
    let duration = args.get_f64("duration", 0.2).map_err(|e| anyhow!(e))?;
    if duration <= 0.0 {
        return Err(anyhow!("duration must be positive"));
    }
    let tenants: Vec<usize> = args
        .get_list("tenants", "1000,10000")
        .iter()
        .map(|t| {
            t.parse::<usize>()
                .map_err(|_| anyhow!("bad tenant count {t}"))
        })
        .collect::<Result<_>>()?;
    if tenants.is_empty() {
        return Err(anyhow!("--tenants needs at least one count"));
    }
    let scheduler = args.get("scheduler", "miriam").to_string();
    let default_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let threads = args
        .get_usize("threads", default_threads)
        .map_err(|e| anyhow!(e))?;
    let out = args.get("out", "BENCH_scale.json");

    println!("# scale-sim: tenants {:?} on {} ({} SMs), {duration}s of \
              arrivals each, scheduler {scheduler}, {threads} thread(s)",
             tenants, gpu.name, gpu.num_sms);
    let t0 = std::time::Instant::now();
    let grid =
        scale::run_scale_grid(&gpu, &tenants, duration * 1e6, &scheduler,
                              threads)
            .map_err(|e| anyhow!(e))?;
    let wall = t0.elapsed().as_secs_f64();
    println!("{:>8} {:>10} {:>10} {:>8} {:>8} {:>12} {:>12}",
             "tenants", "offered", "served", "miss", "sketch",
             "bytes/tenant", "worst p99");
    println!("{:>8} {:>10} {:>10} {:>8} {:>8} {:>12} {:>12}",
             "", "", "", "", "", "", "(ms)");
    let mut events: u64 = 0;
    for c in &grid.cells {
        events += c.events;
        let p99 = if c.worst_tenant_p99_us.is_nan() {
            "-".to_string()
        } else {
            format!("{:.2}", c.worst_tenant_p99_us / 1e3)
        };
        println!("{:>8} {:>10} {:>10} {:>8} {:>8} {:>12.1} {:>12}",
                 c.tenants, c.offered, c.served, c.deadline_misses,
                 c.sketch_tenants, c.bytes_per_tenant, p99);
    }
    // Host-timed throughput: stdout only, never in the JSON.
    if wall > 0.0 {
        println!("# {events} engine events in {wall:.2}s wall \
                  ({:.0} events/sec)", events as f64 / wall);
    }
    std::fs::write(out, grid.to_json())?;
    println!("wrote {out}");
    Ok(())
}

/// Resolve `--scenario all|n1,n2,...` for `gen-sim` against the
/// generation family plus the standalone differential scenario.
fn resolve_gen_scenarios(args: &Args, dur_us: f64)
                         -> Result<Vec<generation::GenScenarioSpec>> {
    let which = args.get("scenario", "all");
    if which.eq_ignore_ascii_case("all") {
        return Ok(generation::gen_family(dur_us));
    }
    args.get_list("scenario", "")
        .iter()
        .map(|n| {
            generation::gen_by_name(n, dur_us)
                .ok_or_else(|| anyhow!("unknown gen scenario {n}"))
        })
        .collect()
}

/// `gen-sim` (ISSUE 10 tentpole): the autoregressive serving grid —
/// prefill/decode request state machines with KV-cache residency and
/// token-level SLOs through the online core — scenarios × admission
/// policies plus solo-criticals / sequential / continuous-batching
/// comparison rows, stdout table plus `BENCH_gen.json`. The JSON is
/// byte-deterministic per seed across `--threads` and repeats
/// (`rust/tests/gen_determinism.rs` pins both).
fn gen_sim(args: &Args) -> Result<()> {
    let platform = args.get("platform", "rtx2060");
    let gpu = GpuSpec::by_name(platform)
        .ok_or_else(|| anyhow!("unknown platform {platform}"))?;
    let duration = args.get_f64("duration", 0.2).map_err(|e| anyhow!(e))?;
    if duration <= 0.0 {
        return Err(anyhow!("duration must be positive"));
    }
    let dur_us = duration * 1e6;
    let scenarios = resolve_gen_scenarios(args, dur_us)?;
    let policies = args
        .get_list("policy", "none,token-bucket,deadline-feasible")
        .iter()
        .map(|p| {
            AdmissionPolicy::parse(p)
                .ok_or_else(|| anyhow!("unknown policy {p}"))
        })
        .collect::<Result<Vec<_>>>()?;
    let default_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let threads = args
        .get_usize("threads", default_threads)
        .map_err(|e| anyhow!(e))?;
    let batch_window_us = if args.has("batch-window-ms") {
        Some(args.get_f64("batch-window-ms", 0.15)
            .map_err(|e| anyhow!(e))? * 1e3)
    } else {
        None // the batched comparison row uses GEN_BATCH_WINDOW_US
    };
    let opts = gen::GenOpts {
        scheduler: args.get("scheduler", "miriam").to_string(),
        policy: AdmissionPolicy::Open, // per-cell policy comes from the grid
        admission: admission_from_args(args)?,
        seed: seed_from_args(args)?,
        batch_window_us,
    };
    let out = args.get("out", "BENCH_gen.json");

    println!("# gen-sim: {} scenario(s) x {} policy(ies) (+3 comparison \
              rows each) on {} ({} SMs), {duration}s of arrivals each, \
              scheduler {}, {threads} thread(s)",
             scenarios.len(), policies.len(), gpu.name, gpu.num_sms,
             opts.scheduler);
    let grid = gen::run_gen_grid(&gpu, &scenarios, &policies, &opts, threads)
        .map_err(|e| anyhow!(e))?;
    println!("{:<14} {:<11} {:<18} {:>7} {:>6} {:>7} {:>6} {:>6} {:>9} \
              {:>9} {:>9}",
             "scenario", "kind", "policy", "admit", "shed", "tokens",
             "evict", "preem", "ttft p50", "ttft p99", "tok/s");
    println!("{:<14} {:<11} {:<18} {:>7} {:>6} {:>7} {:>6} {:>6} {:>9} \
              {:>9} {:>9}",
             "", "", "", "", "", "", "", "", "(ms)", "(ms)", "");
    for c in &grid.cells {
        println!("{:<14} {:<11} {:<18} {:>7} {:>6} {:>7} {:>6} {:>6} \
                  {:>9.2} {:>9.2} {:>9.0}",
                 c.scenario, c.kind, c.policy.name(), c.admitted(),
                 c.shed(), c.tokens, c.evictions, c.preempted_steps,
                 c.crit_ttft_quantile_us(0.5) / 1e3,
                 c.crit_ttft_p99_us() / 1e3,
                 c.tokens_per_sec());
    }
    std::fs::write(out, grid.to_json())?;
    println!("wrote {out}");
    Ok(())
}

fn infer(args: &Args) -> Result<()> {
    use miriam::runtime::artifacts::npy_rand;
    let model = args
        .flags
        .get("model")
        .ok_or_else(|| anyhow!("--model is required"))?
        .clone();
    let artifacts = args.get("artifacts", "artifacts");
    let manifest = Manifest::load(artifacts)?;
    let entry = manifest.entry(&model)?.clone();
    let mut rt = miriam::runtime::Runtime::new(manifest)?;
    println!("platform: {}", rt.platform());
    let m = rt.load(&model)?;
    let n: usize = m.input_shapes[0].iter().product();
    let seed = entry.golden.as_ref().map(|g| g.input_seed).unwrap_or(42);
    let input = npy_rand::randn(seed as u32, n);
    let t0 = std::time::Instant::now();
    let out = m.run_f32(&[input])?;
    println!("{model}: output {:?} in {:.2} ms", &out[..out.len().min(10)],
             t0.elapsed().as_secs_f64() * 1e3);
    if let Some(g) = &entry.golden {
        let max_err = out
            .iter()
            .zip(&g.output)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("golden max abs err: {max_err:.3e} {}",
                 if max_err < 1e-3 { "OK" } else { "MISMATCH" });
        if max_err >= 1e-3 {
            return Err(anyhow!("golden mismatch"));
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env().map_err(|e| anyhow!(e))?;
    match args.positional.first().map(String::as_str) {
        Some("simulate") => simulate(&args),
        Some("scenarios") => scenarios(&args),
        Some("sweep") => sweep_cmd(&args),
        Some("serve-sim") => serve_sim(&args),
        Some("fleet-sim") => fleet_sim(&args),
        Some("scale-sim") => scale_sim(&args),
        Some("gen-sim") => gen_sim(&args),
        Some("infer") => infer(&args),
        Some("artifacts") => {
            let m = Manifest::load(args.get("artifacts", "artifacts"))?;
            for e in &m.artifacts {
                println!("{:<16} kind={:<14} file={}", e.name, e.kind,
                         e.file.as_deref().unwrap_or("-"));
            }
            Ok(())
        }
        _ => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}
