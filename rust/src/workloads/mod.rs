//! DNN workloads: model kernel descriptors, arrival processes, the MDTB
//! benchmark (paper Table 2), the LGSVL case-study trace (§8.5), and the
//! declarative scenario harness (N-tenant mixed-criticality scenarios
//! beyond the paper's benchmark).

pub mod arrival;
pub mod lgsvl;
pub mod mdtb;
pub mod models;
pub mod rng;
pub mod scenario;

pub use arrival::Arrival;
pub use mdtb::{Source, Workload, WorkloadSpec};
pub use models::{ModelDesc, ModelRef};
pub use rng::Rng;
pub use scenario::{ScenarioGen, ScenarioSpec, SourceSpec};
