//! DNN workloads: model kernel descriptors, arrival processes, the MDTB
//! benchmark (paper Table 2) and the LGSVL case-study trace (§8.5).

pub mod arrival;
pub mod lgsvl;
pub mod mdtb;
pub mod models;
pub mod rng;

pub use arrival::Arrival;
pub use mdtb::{Source, Workload, WorkloadSpec};
pub use models::{ModelDesc, ModelRef};
pub use rng::Rng;
