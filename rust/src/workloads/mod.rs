//! DNN workloads: model kernel descriptors, arrival processes, the MDTB
//! benchmark (paper Table 2), the LGSVL case-study trace (§8.5), the
//! declarative scenario harness (N-tenant mixed-criticality scenarios
//! beyond the paper's benchmark), and the autoregressive generation
//! family ([`generation`]: prefill/decode kernel graphs, KV-cache
//! footprints, token-level SLOs).

pub mod arrival;
pub mod generation;
pub mod lgsvl;
pub mod mdtb;
pub mod models;
pub mod rng;
pub mod scenario;

pub use arrival::Arrival;
pub use mdtb::{Source, Workload, WorkloadSpec};
pub use models::{ModelDesc, ModelRef};
pub use rng::Rng;
pub use scenario::{ScenarioGen, ScenarioSpec, SourceSpec};
