//! Declarative mixed-criticality scenarios beyond MDTB (ISSUE 2
//! tentpole).
//!
//! The paper evaluates on four fixed two-source workloads (Table 2) plus
//! the LGSVL trace; the ROADMAP north star asks for "as many scenarios as
//! you can imagine". A [`ScenarioSpec`] describes an N-tenant workload
//! declaratively — per-source model, criticality, optional deadline, and
//! arrival process (including the bursty MMPP / ramp / trace-replay
//! processes of [`crate::workloads::arrival`]) — and [`family`] enumerates
//! a named family of deadline-tagged, bursty, skewed scenarios (2–6
//! tenants) that the conformance-trace suite
//! (`rust/tests/conformance_traces.rs`) drives through every scheduler.
//! [`ScenarioGen`] extends the family with seeded random scenarios for
//! open-ended sweeps (`miriam scenarios --gen N`).

use std::sync::Arc;

use crate::gpu::kernel::Criticality;
use crate::workloads::arrival::Arrival;
use crate::workloads::mdtb::{Source, Workload};
use crate::workloads::models;
use crate::workloads::rng::Rng;

/// One declarative request source of a scenario.
#[derive(Debug, Clone)]
pub struct SourceSpec {
    /// Model name, resolved through [`models::by_name`] at build time.
    pub model: String,
    /// Task class of every request from this source.
    pub criticality: Criticality,
    /// How requests arrive.
    pub arrival: Arrival,
    /// Optional end-to-end deadline (us); completions later than this are
    /// counted in `RunStats::deadline_misses_*`.
    pub deadline_us: Option<f64>,
}

/// A complete declarative scenario: N tenants over a simulated window.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    /// Scenario name (unique within the family).
    pub name: String,
    /// The tenants, in source order.
    pub sources: Vec<SourceSpec>,
    /// Arrival-generation window (us).
    pub duration_us: f64,
    /// RNG seed for stochastic arrivals (the driver derives every random
    /// draw of the run from it, so a scenario is fully reproducible).
    pub seed: u64,
}

impl ScenarioSpec {
    /// Number of request sources (tenants).
    pub fn tenants(&self) -> usize {
        self.sources.len()
    }

    /// Stable per-tenant label for serving reports:
    /// `t{i}-{model}-{critical|normal}` (e.g. `t0-gru-critical`).
    pub fn tenant_label(&self, i: usize) -> String {
        let s = &self.sources[i];
        let class = match s.criticality {
            Criticality::Critical => "critical",
            Criticality::Normal => "normal",
        };
        format!("t{i}-{}-{class}", s.model)
    }

    /// Number of critical tenants.
    pub fn criticals(&self) -> usize {
        self.sources
            .iter()
            .filter(|s| s.criticality == Criticality::Critical)
            .count()
    }

    /// Resolve model names and materialize the runnable [`Workload`].
    /// Panics on an unknown model name, mirroring `WorkloadSpec::build`.
    pub fn build(&self) -> Workload {
        Workload {
            name: self.name.clone(),
            sources: self
                .sources
                .iter()
                .map(|s| Source {
                    model: Arc::new(models::by_name(&s.model).unwrap_or_else(
                        || {
                            panic!(
                                "unknown model {} in scenario {}",
                                s.model, self.name
                            )
                        },
                    )),
                    arrival: s.arrival.clone(),
                    criticality: s.criticality,
                    deadline_us: s.deadline_us,
                })
                .collect(),
            duration_us: self.duration_us,
            seed: self.seed,
        }
    }
}

fn crit(model: &str, arrival: Arrival, deadline_us: Option<f64>) -> SourceSpec {
    SourceSpec {
        model: model.into(),
        criticality: Criticality::Critical,
        arrival,
        deadline_us,
    }
}

fn norm(model: &str, arrival: Arrival) -> SourceSpec {
    SourceSpec {
        model: model.into(),
        criticality: Criticality::Normal,
        arrival,
        deadline_us: None,
    }
}

/// A jittered-periodic recorded arrival list (what a rosbag replay of a
/// sensor topic looks like), regenerated deterministically from `seed` —
/// the input to [`Arrival::Replay`] scenarios.
pub fn recorded_trace(
    duration_us: f64,
    rate_hz: f64,
    jitter_us: f64,
    seed: u64,
) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let period = 1e6 / rate_hz;
    let mut out = Vec::new();
    let mut t = 0.0;
    while t < duration_us {
        let j = (rng.next_f64() * 2.0 - 1.0) * jitter_us;
        out.push((t + j).max(0.0));
        t += period;
    }
    out
}

/// The named scenario family (>= 8 scenarios, 2–6 tenants each, mixed
/// criticality, skewed and bursty load — all beyond the MDTB shapes).
/// Rates are deliberately high (the ROADMAP's heavy-traffic regime) so
/// even short windows exercise queueing; deadlines tag the critical
/// tenants that model hard real-time tasks.
pub fn family(duration_us: f64) -> Vec<ScenarioSpec> {
    vec![
        // 2 tenants: bursty critical RNN vs closed-loop filler.
        ScenarioSpec {
            name: "duo-burst".into(),
            sources: vec![
                crit(
                    "gru",
                    Arrival::Mmpp {
                        on_hz: 200.0,
                        off_hz: 5.0,
                        mean_on_us: 5_000.0,
                        mean_off_us: 10_000.0,
                    },
                    Some(30_000.0),
                ),
                norm("cifarnet", Arrival::ClosedLoop { clients: 2 }),
            ],
            duration_us,
            seed: 0x2B1,
        },
        // 2 tenants: trace-replay critical (recorded jittered 50 Hz sensor)
        // vs closed-loop filler.
        ScenarioSpec {
            name: "duo-replay".into(),
            sources: vec![
                crit(
                    "squeezenet",
                    Arrival::replay(recorded_trace(
                        duration_us,
                        50.0,
                        1_500.0,
                        0x2B2,
                    )),
                    Some(40_000.0),
                ),
                norm("cifarnet", Arrival::ClosedLoop { clients: 2 }),
            ],
            duration_us,
            seed: 0x2B2,
        },
        // 3 tenants, skewed: one fat closed-loop normal plus a trickle.
        ScenarioSpec {
            name: "trio-skew".into(),
            sources: vec![
                crit(
                    "alexnet",
                    Arrival::Uniform { rate_hz: 50.0 },
                    Some(25_000.0),
                ),
                norm("cifarnet", Arrival::ClosedLoop { clients: 4 }),
                norm("squeezenet", Arrival::Poisson { rate_hz: 40.0 }),
            ],
            duration_us,
            seed: 0x2B3,
        },
        // 3 tenants: critical load ramps 10x across the window.
        ScenarioSpec {
            name: "trio-ramp".into(),
            sources: vec![
                crit(
                    "gru",
                    Arrival::Ramp { start_hz: 10.0, end_hz: 100.0 },
                    Some(20_000.0),
                ),
                norm("cifarnet", Arrival::ClosedLoop { clients: 2 }),
                norm("alexnet", Arrival::Poisson { rate_hz: 30.0 }),
            ],
            duration_us,
            seed: 0x2B4,
        },
        // 4 tenants, two critical classes with different arrival shapes.
        ScenarioSpec {
            name: "quad-dual-crit".into(),
            sources: vec![
                crit(
                    "squeezenet",
                    Arrival::Uniform { rate_hz: 40.0 },
                    Some(30_000.0),
                ),
                crit(
                    "gru",
                    Arrival::Mmpp {
                        on_hz: 150.0,
                        off_hz: 0.0,
                        mean_on_us: 4_000.0,
                        mean_off_us: 8_000.0,
                    },
                    Some(25_000.0),
                ),
                norm("cifarnet", Arrival::ClosedLoop { clients: 3 }),
                norm("alexnet", Arrival::ClosedLoop { clients: 1 }),
            ],
            duration_us,
            seed: 0x2B5,
        },
        // 4 tenants: steady critical vs three desynchronized bursty
        // best-effort tenants.
        ScenarioSpec {
            name: "quad-bursty".into(),
            sources: vec![
                crit(
                    "alexnet",
                    Arrival::Uniform { rate_hz: 30.0 },
                    Some(35_000.0),
                ),
                norm(
                    "cifarnet",
                    Arrival::Mmpp {
                        on_hz: 300.0,
                        off_hz: 10.0,
                        mean_on_us: 3_000.0,
                        mean_off_us: 9_000.0,
                    },
                ),
                norm(
                    "cifarnet",
                    Arrival::Mmpp {
                        on_hz: 200.0,
                        off_hz: 0.0,
                        mean_on_us: 6_000.0,
                        mean_off_us: 6_000.0,
                    },
                ),
                norm("squeezenet", Arrival::Poisson { rate_hz: 25.0 }),
            ],
            duration_us,
            seed: 0x2B6,
        },
        // 5 tenants: everything at once (the saturation storm).
        ScenarioSpec {
            name: "five-storm".into(),
            sources: vec![
                crit(
                    "gru",
                    Arrival::Uniform { rate_hz: 60.0 },
                    Some(18_000.0),
                ),
                crit(
                    "squeezenet",
                    Arrival::Poisson { rate_hz: 30.0 },
                    Some(40_000.0),
                ),
                norm("cifarnet", Arrival::ClosedLoop { clients: 3 }),
                norm(
                    "cifarnet",
                    Arrival::Mmpp {
                        on_hz: 250.0,
                        off_hz: 5.0,
                        mean_on_us: 2_000.0,
                        mean_off_us: 10_000.0,
                    },
                ),
                norm("alexnet", Arrival::Poisson { rate_hz: 20.0 }),
            ],
            duration_us,
            seed: 0x2B7,
        },
        // 6 tenants: the widest mix — two critical, four skewed normals,
        // one of them ramping.
        ScenarioSpec {
            name: "six-saturate".into(),
            sources: vec![
                crit(
                    "alexnet",
                    Arrival::Uniform { rate_hz: 25.0 },
                    Some(45_000.0),
                ),
                crit(
                    "gru",
                    Arrival::Poisson { rate_hz: 40.0 },
                    Some(22_000.0),
                ),
                norm("cifarnet", Arrival::ClosedLoop { clients: 2 }),
                norm("cifarnet", Arrival::ClosedLoop { clients: 2 }),
                norm(
                    "squeezenet",
                    Arrival::Mmpp {
                        on_hz: 120.0,
                        off_hz: 8.0,
                        mean_on_us: 5_000.0,
                        mean_off_us: 7_000.0,
                    },
                ),
                norm(
                    "cifarnet",
                    Arrival::Ramp { start_hz: 5.0, end_hz: 80.0 },
                ),
            ],
            duration_us,
            seed: 0x2B8,
        },
    ]
}

/// The resilience stress scenario (ISSUE 6): a flash crowd — a hard
/// Mmpp best-effort burst plus closed-loop filler — under a steady
/// deadline-bearing critical tenant. Built to be run with the
/// `flash-crowd-outage` storm preset, which drops a device on top of
/// the crowd's peak. Kept **out of [`family`]** so the default sweep /
/// serve / fleet grids (and their committed baselines) are untouched;
/// reachable by name (`--scenario flash-crowd`) and used by
/// `benches/resilience.rs`.
pub fn flash_crowd(duration_us: f64) -> ScenarioSpec {
    ScenarioSpec {
        name: "flash-crowd".into(),
        sources: vec![
            crit(
                "gru",
                Arrival::Uniform { rate_hz: 50.0 },
                Some(25_000.0),
            ),
            norm(
                "cifarnet",
                Arrival::Mmpp {
                    on_hz: 400.0,
                    off_hz: 5.0,
                    mean_on_us: 4_000.0,
                    mean_off_us: 12_000.0,
                },
            ),
            norm("squeezenet", Arrival::ClosedLoop { clients: 2 }),
        ],
        duration_us,
        seed: 0x2B9,
    }
}

/// Look up a named scenario by name (case-insensitive): the [`family`]
/// members plus the standalone [`flash_crowd`] stress scenario.
pub fn by_name(name: &str, duration_us: f64) -> Option<ScenarioSpec> {
    family(duration_us)
        .into_iter()
        .chain(std::iter::once(flash_crowd(duration_us)))
        .find(|s| s.name.eq_ignore_ascii_case(name))
}

/// An MDTB Table-2 workload expressed as a [`ScenarioSpec`], so the sweep
/// runner and the engine-throughput bench treat MDTB cells and family
/// scenarios uniformly (ISSUE 3). `build()` of the result materializes the
/// same `Workload` as `WorkloadSpec::build` (same sources, seed, duration).
pub fn from_mdtb(spec: &crate::workloads::mdtb::WorkloadSpec) -> ScenarioSpec {
    ScenarioSpec {
        name: spec.name.clone(),
        sources: vec![
            crit(&spec.critical_model, spec.critical_arrival.clone(), None),
            norm(&spec.normal_model, spec.normal_arrival.clone()),
        ],
        duration_us: spec.duration_us,
        seed: spec.seed,
    }
}

/// All four MDTB workloads as scenarios.
pub fn mdtb_scenarios(duration_us: f64) -> Vec<ScenarioSpec> {
    crate::workloads::mdtb::all(duration_us).iter().map(from_mdtb).collect()
}

/// Pinned (scenario, scheduler) cells whose canonical engine traces are
/// kept as golden files under `rust/tests/golden/` — the semantic-drift
/// anchors of the conformance suite. Record/refresh with
/// `miriam scenarios --record-golden rust/tests/golden`
/// (see EXPERIMENTS.md §Scenarios).
pub const GOLDEN_CELLS: [(&str, &str); 4] = [
    ("duo-burst", "sequential"),
    ("duo-replay", "miriam"),
    ("trio-skew", "multistream"),
    ("quad-dual-crit", "ib"),
];

/// Pinned simulated duration (us) for golden traces. Goldens recorded at
/// any other duration will not match.
pub const GOLDEN_DURATION_US: f64 = 40_000.0;

/// Pinned GPU preset for golden traces — the conformance suite replays
/// goldens on this platform only, so recording must use it too.
pub const GOLDEN_PLATFORM: &str = "rtx2060";

/// File name of a golden trace cell.
pub fn golden_file_name(scenario: &str, scheduler: &str) -> String {
    format!("{scenario}__{scheduler}.trace.json")
}

/// GPU presets covered by the *per-device* golden traces (ISSUE 5
/// satellite): the two edge parts beyond [`GOLDEN_PLATFORM`], so a
/// contention-model or scheduler change that only misbehaves on a small
/// device (fewer SMs, tighter bandwidth) fails loudly too.
pub const DEVICE_GOLDEN_PLATFORMS: [&str; 2] = ["xavier", "tx2"];

/// Family scenarios pinned per device platform — one bursty duo, one
/// skewed trio, each replayed under every scheduler on every
/// [`DEVICE_GOLDEN_PLATFORMS`] entry (2 × 2 × 4 = 16 anchor cells).
pub const DEVICE_GOLDEN_SCENARIOS: [&str; 2] = ["duo-burst", "trio-skew"];

/// Subdirectory of the golden dir holding the per-device anchors
/// (`rust/tests/golden/devices/`), so the two golden sets keep separate
/// bootstrap states.
pub const DEVICE_GOLDEN_SUBDIR: &str = "devices";

/// File name of a per-device golden trace cell (platform-qualified).
pub fn device_golden_file_name(platform: &str, scenario: &str,
                               scheduler: &str) -> String {
    format!("{platform}__{scenario}__{scheduler}.trace.json")
}

/// Seeded random-scenario generator: extends the named family with an
/// unbounded stream of valid (2–6 tenant, >= 1 critical, >= 1 normal)
/// scenarios for sweeps. Deterministic per seed.
pub struct ScenarioGen {
    rng: Rng,
    duration_us: f64,
    next_idx: usize,
}

/// Model pool for generated scenarios: the lighter MDTB models, so a
/// generated scenario stays simulable in milliseconds.
const GEN_MODELS: [&str; 4] = ["cifarnet", "squeezenet", "alexnet", "gru"];

impl ScenarioGen {
    /// A generator whose stream of scenarios is fully determined by
    /// `seed`; every generated scenario spans `duration_us`.
    pub fn new(seed: u64, duration_us: f64) -> Self {
        ScenarioGen { rng: Rng::new(seed), duration_us, next_idx: 0 }
    }

    fn random_arrival(&mut self, closed_loop_ok: bool) -> Arrival {
        let kinds = if closed_loop_ok { 5 } else { 4 };
        match self.rng.next_below(kinds) {
            0 => Arrival::Uniform {
                rate_hz: 10.0 + self.rng.next_f64() * 60.0,
            },
            1 => Arrival::Poisson {
                rate_hz: 10.0 + self.rng.next_f64() * 60.0,
            },
            2 => Arrival::Mmpp {
                on_hz: 50.0 + self.rng.next_f64() * 250.0,
                off_hz: self.rng.next_f64() * 10.0,
                mean_on_us: 2_000.0 + self.rng.next_f64() * 8_000.0,
                mean_off_us: 2_000.0 + self.rng.next_f64() * 12_000.0,
            },
            3 => {
                let a = 5.0 + self.rng.next_f64() * 40.0;
                let b = 5.0 + self.rng.next_f64() * 80.0;
                Arrival::Ramp { start_hz: a, end_hz: b }
            }
            _ => Arrival::ClosedLoop {
                clients: 1 + self.rng.next_below(3) as u32,
            },
        }
    }

    /// The next generated scenario.
    pub fn next_scenario(&mut self) -> ScenarioSpec {
        let idx = self.next_idx;
        self.next_idx += 1;
        let tenants = 2 + self.rng.next_below(5) as usize; // 2..=6
        let mut sources = Vec::with_capacity(tenants);
        for i in 0..tenants {
            let model =
                GEN_MODELS[self.rng.next_below(GEN_MODELS.len() as u64) as usize];
            // Tenant 0 is always critical and tenant 1 always normal so
            // every scenario is genuinely mixed-criticality; the rest coin-
            // flip. Critical sources stay open-loop (a hard-real-time task
            // does not self-throttle on completions).
            let critical = match i {
                0 => true,
                1 => false,
                _ => self.rng.next_f64() < 0.4,
            };
            if critical {
                let deadline = if self.rng.next_f64() < 0.7 {
                    Some(10_000.0 + self.rng.next_f64() * 60_000.0)
                } else {
                    None
                };
                let arrival = self.random_arrival(false);
                sources.push(crit(model, arrival, deadline));
            } else {
                let arrival = self.random_arrival(true);
                sources.push(norm(model, arrival));
            }
        }
        ScenarioSpec {
            name: format!("gen-{idx}-{tenants}t"),
            sources,
            duration_us: self.duration_us,
            seed: self.rng.next_u64(),
        }
    }

    /// Generate the next `n` scenarios.
    pub fn take(&mut self, n: usize) -> Vec<ScenarioSpec> {
        (0..n).map(|_| self.next_scenario()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_is_large_mixed_and_uniquely_named() {
        let fam = family(50_000.0);
        assert!(fam.len() >= 8, "family has {}", fam.len());
        let mut names: Vec<&str> =
            fam.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), fam.len(), "duplicate scenario names");
        for sc in &fam {
            assert!(
                (2..=6).contains(&sc.tenants()),
                "{}: {} tenants",
                sc.name,
                sc.tenants()
            );
            assert!(sc.criticals() >= 1, "{}: no critical tenant", sc.name);
            assert!(
                sc.criticals() < sc.tenants(),
                "{}: no normal tenant",
                sc.name
            );
        }
    }

    #[test]
    fn family_builds_runnable_workloads() {
        for sc in family(50_000.0) {
            let wl = sc.build();
            assert_eq!(wl.sources.len(), sc.tenants());
            assert_eq!(wl.name, sc.name);
            for src in &wl.sources {
                assert!(!src.model.kernels.is_empty());
            }
        }
    }

    #[test]
    fn family_exercises_the_new_arrival_processes() {
        let fam = family(50_000.0);
        let has = |pred: fn(&Arrival) -> bool| {
            fam.iter().flat_map(|s| &s.sources).any(|s| pred(&s.arrival))
        };
        assert!(has(|a| matches!(a, Arrival::Mmpp { .. })), "no MMPP");
        assert!(has(|a| matches!(a, Arrival::Ramp { .. })), "no ramp");
        assert!(has(|a| matches!(a, Arrival::Replay { .. })), "no replay");
        assert!(
            fam.iter()
                .flat_map(|s| &s.sources)
                .any(|s| s.deadline_us.is_some()),
            "no deadline-tagged source"
        );
    }

    #[test]
    fn by_name_resolves_and_golden_cells_exist() {
        assert!(by_name("duo-burst", 1e5).is_some());
        assert!(by_name("DUO-BURST", 1e5).is_some());
        assert!(by_name("mdtb-a", 1e5).is_none());
        for (sc, _sched) in GOLDEN_CELLS {
            assert!(
                by_name(sc, GOLDEN_DURATION_US).is_some(),
                "golden cell references unknown scenario {sc}"
            );
        }
        assert_eq!(
            golden_file_name("duo-burst", "ib"),
            "duo-burst__ib.trace.json"
        );
    }

    #[test]
    fn device_golden_cells_name_real_platforms_and_scenarios() {
        use crate::gpu::spec::GpuSpec;
        for p in DEVICE_GOLDEN_PLATFORMS {
            let spec = GpuSpec::by_name(p)
                .unwrap_or_else(|| panic!("unknown device platform {p}"));
            assert_eq!(spec.name, p, "device goldens need canonical names");
            assert_ne!(p, GOLDEN_PLATFORM,
                       "device goldens must extend, not duplicate, the \
                        main set");
        }
        for sc in DEVICE_GOLDEN_SCENARIOS {
            assert!(by_name(sc, GOLDEN_DURATION_US).is_some(),
                    "device golden references unknown scenario {sc}");
        }
        assert_eq!(
            device_golden_file_name("tx2", "duo-burst", "ib"),
            "tx2__duo-burst__ib.trace.json"
        );
    }

    #[test]
    fn generator_is_deterministic_and_valid() {
        let a = ScenarioGen::new(7, 40_000.0).take(12);
        let b = ScenarioGen::new(7, 40_000.0).take(12);
        assert_eq!(a.len(), 12);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.tenants(), y.tenants());
        }
        for sc in &a {
            assert!((2..=6).contains(&sc.tenants()), "{}", sc.name);
            assert!(sc.criticals() >= 1 && sc.criticals() < sc.tenants());
            sc.build(); // all model names resolve
            for s in &sc.sources {
                if s.criticality == Criticality::Critical {
                    assert!(
                        !s.arrival.is_closed_loop(),
                        "{}: closed-loop critical",
                        sc.name
                    );
                }
            }
        }
        let c = ScenarioGen::new(8, 40_000.0).take(12);
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.seed != y.seed),
            "different gen seeds produced identical scenarios"
        );
    }

    #[test]
    fn mdtb_scenarios_match_their_workload_specs() {
        let scens = mdtb_scenarios(1e5);
        let specs = crate::workloads::mdtb::all(1e5);
        assert_eq!(scens.len(), 4);
        for (sc, spec) in scens.iter().zip(&specs) {
            assert_eq!(sc.name, spec.name);
            assert_eq!(sc.seed, spec.seed);
            let a = sc.build();
            let b = spec.build();
            assert_eq!(a.sources.len(), b.sources.len());
            for (x, y) in a.sources.iter().zip(&b.sources) {
                assert_eq!(x.model.name, y.model.name);
                assert_eq!(x.criticality, y.criticality);
                assert_eq!(x.deadline_us, y.deadline_us);
            }
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.duration_us, b.duration_us);
        }
    }

    #[test]
    fn recorded_trace_is_sorted_after_replay_wrap() {
        let times = recorded_trace(100_000.0, 50.0, 1_500.0, 42);
        assert_eq!(times.len(), 5);
        let a = Arrival::replay(times);
        let s = a.schedule(100_000.0, &mut Rng::new(1));
        for w in s.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert!(s.iter().all(|t| *t >= 0.0));
    }
}
