//! Declarative mixed-criticality scenarios beyond MDTB (ISSUE 2
//! tentpole).
//!
//! The paper evaluates on four fixed two-source workloads (Table 2) plus
//! the LGSVL trace; the ROADMAP north star asks for "as many scenarios as
//! you can imagine". A [`ScenarioSpec`] describes an N-tenant workload
//! declaratively — per-source model, criticality, optional deadline, and
//! arrival process (including the bursty MMPP / ramp / trace-replay
//! processes of [`crate::workloads::arrival`]) — and [`family`] enumerates
//! a named family of deadline-tagged, bursty, skewed scenarios (2–6
//! tenants) that the conformance-trace suite
//! (`rust/tests/conformance_traces.rs`) drives through every scheduler.
//! [`ScenarioGen`] extends the family with seeded random scenarios for
//! open-ended sweeps (`miriam scenarios --gen N`). [`ScaleSpec`]
//! (ISSUE 7) compiles tiered 1k–100k-tenant populations with
//! heavy-tailed rates and diurnal/flash-crowd modulation into lazy
//! [`Arrival::Modulated`] sources for `miriam scale-sim`.

use std::sync::Arc;

use crate::gpu::kernel::Criticality;
use crate::workloads::arrival::{Arrival, RateCurve};
use crate::workloads::mdtb::{Source, Workload};
use crate::workloads::models;
use crate::workloads::rng::Rng;

/// One declarative request source of a scenario.
#[derive(Debug, Clone)]
pub struct SourceSpec {
    /// Model name, resolved through [`models::by_name`] at build time.
    pub model: String,
    /// Task class of every request from this source.
    pub criticality: Criticality,
    /// How requests arrive.
    pub arrival: Arrival,
    /// Optional end-to-end deadline (us); completions later than this are
    /// counted in `RunStats::deadline_misses_*`.
    pub deadline_us: Option<f64>,
}

/// A complete declarative scenario: N tenants over a simulated window.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    /// Scenario name (unique within the family).
    pub name: String,
    /// The tenants, in source order.
    pub sources: Vec<SourceSpec>,
    /// Arrival-generation window (us).
    pub duration_us: f64,
    /// RNG seed for stochastic arrivals (the driver derives every random
    /// draw of the run from it, so a scenario is fully reproducible).
    pub seed: u64,
}

impl ScenarioSpec {
    /// Number of request sources (tenants).
    pub fn tenants(&self) -> usize {
        self.sources.len()
    }

    /// Stable per-tenant label for serving reports:
    /// `t{i}-{model}-{critical|normal}` (e.g. `t0-gru-critical`).
    pub fn tenant_label(&self, i: usize) -> String {
        let s = &self.sources[i];
        let class = match s.criticality {
            Criticality::Critical => "critical",
            Criticality::Normal => "normal",
        };
        format!("t{i}-{}-{class}", s.model)
    }

    /// Number of critical tenants.
    pub fn criticals(&self) -> usize {
        self.sources
            .iter()
            .filter(|s| s.criticality == Criticality::Critical)
            .count()
    }

    /// Resolve model names and materialize the runnable [`Workload`].
    /// Panics on an unknown model name, mirroring `WorkloadSpec::build`.
    pub fn build(&self) -> Workload {
        Workload {
            name: self.name.clone(),
            sources: self
                .sources
                .iter()
                .map(|s| Source {
                    model: Arc::new(models::by_name(&s.model).unwrap_or_else(
                        || {
                            panic!(
                                "unknown model {} in scenario {}",
                                s.model, self.name
                            )
                        },
                    )),
                    arrival: s.arrival.clone(),
                    criticality: s.criticality,
                    deadline_us: s.deadline_us,
                })
                .collect(),
            duration_us: self.duration_us,
            seed: self.seed,
        }
    }
}

fn crit(model: &str, arrival: Arrival, deadline_us: Option<f64>) -> SourceSpec {
    SourceSpec {
        model: model.into(),
        criticality: Criticality::Critical,
        arrival,
        deadline_us,
    }
}

fn norm(model: &str, arrival: Arrival) -> SourceSpec {
    SourceSpec {
        model: model.into(),
        criticality: Criticality::Normal,
        arrival,
        deadline_us: None,
    }
}

/// A jittered-periodic recorded arrival list (what a rosbag replay of a
/// sensor topic looks like), regenerated deterministically from `seed` —
/// the input to [`Arrival::Replay`] scenarios.
pub fn recorded_trace(
    duration_us: f64,
    rate_hz: f64,
    jitter_us: f64,
    seed: u64,
) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let period = 1e6 / rate_hz;
    let mut out = Vec::new();
    let mut t = 0.0;
    while t < duration_us {
        let j = (rng.next_f64() * 2.0 - 1.0) * jitter_us;
        out.push((t + j).max(0.0));
        t += period;
    }
    out
}

/// The named scenario family (>= 8 scenarios, 2–6 tenants each, mixed
/// criticality, skewed and bursty load — all beyond the MDTB shapes).
/// Rates are deliberately high (the ROADMAP's heavy-traffic regime) so
/// even short windows exercise queueing; deadlines tag the critical
/// tenants that model hard real-time tasks.
pub fn family(duration_us: f64) -> Vec<ScenarioSpec> {
    vec![
        // 2 tenants: bursty critical RNN vs closed-loop filler.
        ScenarioSpec {
            name: "duo-burst".into(),
            sources: vec![
                crit(
                    "gru",
                    Arrival::Mmpp {
                        on_hz: 200.0,
                        off_hz: 5.0,
                        mean_on_us: 5_000.0,
                        mean_off_us: 10_000.0,
                    },
                    Some(30_000.0),
                ),
                norm("cifarnet", Arrival::ClosedLoop { clients: 2 }),
            ],
            duration_us,
            seed: 0x2B1,
        },
        // 2 tenants: trace-replay critical (recorded jittered 50 Hz sensor)
        // vs closed-loop filler.
        ScenarioSpec {
            name: "duo-replay".into(),
            sources: vec![
                crit(
                    "squeezenet",
                    Arrival::replay(recorded_trace(
                        duration_us,
                        50.0,
                        1_500.0,
                        0x2B2,
                    )),
                    Some(40_000.0),
                ),
                norm("cifarnet", Arrival::ClosedLoop { clients: 2 }),
            ],
            duration_us,
            seed: 0x2B2,
        },
        // 3 tenants, skewed: one fat closed-loop normal plus a trickle.
        ScenarioSpec {
            name: "trio-skew".into(),
            sources: vec![
                crit(
                    "alexnet",
                    Arrival::Uniform { rate_hz: 50.0 },
                    Some(25_000.0),
                ),
                norm("cifarnet", Arrival::ClosedLoop { clients: 4 }),
                norm("squeezenet", Arrival::Poisson { rate_hz: 40.0 }),
            ],
            duration_us,
            seed: 0x2B3,
        },
        // 3 tenants: critical load ramps 10x across the window.
        ScenarioSpec {
            name: "trio-ramp".into(),
            sources: vec![
                crit(
                    "gru",
                    Arrival::Ramp { start_hz: 10.0, end_hz: 100.0 },
                    Some(20_000.0),
                ),
                norm("cifarnet", Arrival::ClosedLoop { clients: 2 }),
                norm("alexnet", Arrival::Poisson { rate_hz: 30.0 }),
            ],
            duration_us,
            seed: 0x2B4,
        },
        // 4 tenants, two critical classes with different arrival shapes.
        ScenarioSpec {
            name: "quad-dual-crit".into(),
            sources: vec![
                crit(
                    "squeezenet",
                    Arrival::Uniform { rate_hz: 40.0 },
                    Some(30_000.0),
                ),
                crit(
                    "gru",
                    Arrival::Mmpp {
                        on_hz: 150.0,
                        off_hz: 0.0,
                        mean_on_us: 4_000.0,
                        mean_off_us: 8_000.0,
                    },
                    Some(25_000.0),
                ),
                norm("cifarnet", Arrival::ClosedLoop { clients: 3 }),
                norm("alexnet", Arrival::ClosedLoop { clients: 1 }),
            ],
            duration_us,
            seed: 0x2B5,
        },
        // 4 tenants: steady critical vs three desynchronized bursty
        // best-effort tenants.
        ScenarioSpec {
            name: "quad-bursty".into(),
            sources: vec![
                crit(
                    "alexnet",
                    Arrival::Uniform { rate_hz: 30.0 },
                    Some(35_000.0),
                ),
                norm(
                    "cifarnet",
                    Arrival::Mmpp {
                        on_hz: 300.0,
                        off_hz: 10.0,
                        mean_on_us: 3_000.0,
                        mean_off_us: 9_000.0,
                    },
                ),
                norm(
                    "cifarnet",
                    Arrival::Mmpp {
                        on_hz: 200.0,
                        off_hz: 0.0,
                        mean_on_us: 6_000.0,
                        mean_off_us: 6_000.0,
                    },
                ),
                norm("squeezenet", Arrival::Poisson { rate_hz: 25.0 }),
            ],
            duration_us,
            seed: 0x2B6,
        },
        // 5 tenants: everything at once (the saturation storm).
        ScenarioSpec {
            name: "five-storm".into(),
            sources: vec![
                crit(
                    "gru",
                    Arrival::Uniform { rate_hz: 60.0 },
                    Some(18_000.0),
                ),
                crit(
                    "squeezenet",
                    Arrival::Poisson { rate_hz: 30.0 },
                    Some(40_000.0),
                ),
                norm("cifarnet", Arrival::ClosedLoop { clients: 3 }),
                norm(
                    "cifarnet",
                    Arrival::Mmpp {
                        on_hz: 250.0,
                        off_hz: 5.0,
                        mean_on_us: 2_000.0,
                        mean_off_us: 10_000.0,
                    },
                ),
                norm("alexnet", Arrival::Poisson { rate_hz: 20.0 }),
            ],
            duration_us,
            seed: 0x2B7,
        },
        // 6 tenants: the widest mix — two critical, four skewed normals,
        // one of them ramping.
        ScenarioSpec {
            name: "six-saturate".into(),
            sources: vec![
                crit(
                    "alexnet",
                    Arrival::Uniform { rate_hz: 25.0 },
                    Some(45_000.0),
                ),
                crit(
                    "gru",
                    Arrival::Poisson { rate_hz: 40.0 },
                    Some(22_000.0),
                ),
                norm("cifarnet", Arrival::ClosedLoop { clients: 2 }),
                norm("cifarnet", Arrival::ClosedLoop { clients: 2 }),
                norm(
                    "squeezenet",
                    Arrival::Mmpp {
                        on_hz: 120.0,
                        off_hz: 8.0,
                        mean_on_us: 5_000.0,
                        mean_off_us: 7_000.0,
                    },
                ),
                norm(
                    "cifarnet",
                    Arrival::Ramp { start_hz: 5.0, end_hz: 80.0 },
                ),
            ],
            duration_us,
            seed: 0x2B8,
        },
    ]
}

/// The resilience stress scenario (ISSUE 6): a flash crowd — a hard
/// Mmpp best-effort burst plus closed-loop filler — under a steady
/// deadline-bearing critical tenant. Built to be run with the
/// `flash-crowd-outage` storm preset, which drops a device on top of
/// the crowd's peak. Kept **out of [`family`]** so the default sweep /
/// serve / fleet grids (and their committed baselines) are untouched;
/// reachable by name (`--scenario flash-crowd`) and used by
/// `benches/resilience.rs`.
pub fn flash_crowd(duration_us: f64) -> ScenarioSpec {
    ScenarioSpec {
        name: "flash-crowd".into(),
        sources: vec![
            crit(
                "gru",
                Arrival::Uniform { rate_hz: 50.0 },
                Some(25_000.0),
            ),
            norm(
                "cifarnet",
                Arrival::Mmpp {
                    on_hz: 400.0,
                    off_hz: 5.0,
                    mean_on_us: 4_000.0,
                    mean_off_us: 12_000.0,
                },
            ),
            norm("squeezenet", Arrival::ClosedLoop { clients: 2 }),
        ],
        duration_us,
        seed: 0x2B9,
    }
}

/// Look up a named scenario by name (case-insensitive): the [`family`]
/// members plus the standalone [`flash_crowd`] stress scenario.
pub fn by_name(name: &str, duration_us: f64) -> Option<ScenarioSpec> {
    family(duration_us)
        .into_iter()
        .chain(std::iter::once(flash_crowd(duration_us)))
        .find(|s| s.name.eq_ignore_ascii_case(name))
}

/// An MDTB Table-2 workload expressed as a [`ScenarioSpec`], so the sweep
/// runner and the engine-throughput bench treat MDTB cells and family
/// scenarios uniformly (ISSUE 3). `build()` of the result materializes the
/// same `Workload` as `WorkloadSpec::build` (same sources, seed, duration).
pub fn from_mdtb(spec: &crate::workloads::mdtb::WorkloadSpec) -> ScenarioSpec {
    ScenarioSpec {
        name: spec.name.clone(),
        sources: vec![
            crit(&spec.critical_model, spec.critical_arrival.clone(), None),
            norm(&spec.normal_model, spec.normal_arrival.clone()),
        ],
        duration_us: spec.duration_us,
        seed: spec.seed,
    }
}

/// All four MDTB workloads as scenarios.
pub fn mdtb_scenarios(duration_us: f64) -> Vec<ScenarioSpec> {
    crate::workloads::mdtb::all(duration_us).iter().map(from_mdtb).collect()
}

/// Pinned (scenario, scheduler) cells whose canonical engine traces are
/// kept as golden files under `rust/tests/golden/` — the semantic-drift
/// anchors of the conformance suite. Record/refresh with
/// `miriam scenarios --record-golden rust/tests/golden`
/// (see EXPERIMENTS.md §Scenarios).
pub const GOLDEN_CELLS: [(&str, &str); 4] = [
    ("duo-burst", "sequential"),
    ("duo-replay", "miriam"),
    ("trio-skew", "multistream"),
    ("quad-dual-crit", "ib"),
];

/// Pinned simulated duration (us) for golden traces. Goldens recorded at
/// any other duration will not match.
pub const GOLDEN_DURATION_US: f64 = 40_000.0;

/// Pinned GPU preset for golden traces — the conformance suite replays
/// goldens on this platform only, so recording must use it too.
pub const GOLDEN_PLATFORM: &str = "rtx2060";

/// Hard-isolation splits pinned by the conformance suite (ISSUE 9):
/// one strict split and its work-conserving spillover variant, both
/// valid on every golden platform (70/30 partitions to ≥1 SM per class
/// on tx2's 2 SMs and up). Grid runners treat these as opt-in columns,
/// like `miriam-ref`.
pub const ISOLATION_GOLDEN_SCHEDULERS: [&str; 2] =
    ["isolation:70/30", "isolation:70/30+spill"];

/// Pinned isolation golden cells (ISSUE 9), recorded alongside
/// [`GOLDEN_CELLS`] by the same writer: each split anchors one bursty
/// and one replay/skew scenario so both the strict and the spillover
/// mask paths have semantic-drift anchors.
pub const ISOLATION_GOLDEN_CELLS: [(&str, &str); 4] = [
    ("duo-burst", "isolation:70/30"),
    ("trio-skew", "isolation:70/30"),
    ("duo-replay", "isolation:70/30+spill"),
    ("quad-dual-crit", "isolation:70/30+spill"),
];

/// Sanitize a scheduler name for use in a golden file name. Identity
/// for the paper schedulers; the isolation family's `:`/`/`/`+` become
/// `-` (`isolation:70/30+spill` → `isolation-70-30-spill`) so cell
/// files never introduce path separators.
pub fn scheduler_file_slug(scheduler: &str) -> String {
    scheduler.replace([':', '/', '+'], "-")
}

/// File name of a golden trace cell.
pub fn golden_file_name(scenario: &str, scheduler: &str) -> String {
    format!("{scenario}__{}.trace.json", scheduler_file_slug(scheduler))
}

/// GPU presets covered by the *per-device* golden traces (ISSUE 5
/// satellite): the two edge parts beyond [`GOLDEN_PLATFORM`], so a
/// contention-model or scheduler change that only misbehaves on a small
/// device (fewer SMs, tighter bandwidth) fails loudly too.
pub const DEVICE_GOLDEN_PLATFORMS: [&str; 2] = ["xavier", "tx2"];

/// Family scenarios pinned per device platform — one bursty duo, one
/// skewed trio, each replayed under every [`crate::coordinator`]
/// scheduler plus both [`ISOLATION_GOLDEN_SCHEDULERS`] splits on every
/// [`DEVICE_GOLDEN_PLATFORMS`] entry (2 × 2 × 6 = 24 anchor cells, so
/// the isolation partition arithmetic is pinned down to tx2's 1/1 SM
/// split).
pub const DEVICE_GOLDEN_SCENARIOS: [&str; 2] = ["duo-burst", "trio-skew"];

/// Subdirectory of the golden dir holding the per-device anchors
/// (`rust/tests/golden/devices/`), so the two golden sets keep separate
/// bootstrap states.
pub const DEVICE_GOLDEN_SUBDIR: &str = "devices";

/// File name of a per-device golden trace cell (platform-qualified).
pub fn device_golden_file_name(platform: &str, scenario: &str,
                               scheduler: &str) -> String {
    format!("{platform}__{scenario}__{}.trace.json",
            scheduler_file_slug(scheduler))
}

/// One tenant tier of a [`ScaleSpec`] (ISSUE 7): a population slice
/// sharing a model, an SLO class, and a slice of the aggregate rate.
///
/// To add a tier, push a `TierSpec` onto [`ScaleSpec::tiers`] (see
/// ARCHITECTURE.md §Event core for the walkthrough): `share` controls
/// how many tenants land in it, `rate_weight` how much of the
/// aggregate offered load it carries. Both columns must each sum to 1
/// across the tier list ([`ScaleSpec::assert_valid`]).
#[derive(Debug, Clone)]
pub struct TierSpec {
    /// Tier name (stable, used in per-tier report keys).
    pub name: String,
    /// Fraction of the tenant population in this tier, in (0, 1].
    pub share: f64,
    /// Model every tenant of the tier runs.
    pub model: String,
    /// Task class of the tier.
    pub criticality: Criticality,
    /// Optional end-to-end deadline (us) for every tenant of the tier.
    pub deadline_us: Option<f64>,
    /// Fraction of [`ScaleSpec::aggregate_hz`] carried by this tier,
    /// in (0, 1].
    pub rate_weight: f64,
}

/// Seeded tiered-tenant scale scenario (ISSUE 7 tentpole): compiles
/// 1k–100k tenants into a [`ScenarioSpec`] of lazy
/// [`Arrival::Modulated`] sources — heavy-tailed per-tenant rates
/// (Pareto weights, tier-normalized so the aggregate offered load is
/// `aggregate_hz` regardless of tenant count), one shared diurnal +
/// flash-crowd [`RateCurve`] — **without materializing any per-tenant
/// arrival vector** (the scale runner pulls arrivals one at a time
/// through [`Arrival::stream`]).
#[derive(Debug, Clone)]
pub struct ScaleSpec {
    /// Scenario name (becomes the compiled [`ScenarioSpec::name`]).
    pub name: String,
    /// Tenant count; must be >= the number of tiers.
    pub tenants: usize,
    /// The tier table, gold-first; shares and rate weights each sum
    /// to 1.
    pub tiers: Vec<TierSpec>,
    /// Total offered load (Hz) across all tenants, held fixed as
    /// `tenants` scales.
    pub aggregate_hz: f64,
    /// Pareto tail index for per-tenant rate weights (`u^(-1/alpha)`);
    /// smaller = heavier tail. Must be positive.
    pub alpha: f64,
    /// Shared modulation curve (diurnal + flash crowd) applied to
    /// every tenant.
    pub curve: RateCurve,
    /// Arrival window (us).
    pub duration_us: f64,
    /// Master seed: tenant `i` draws its rate weight from
    /// `derive_seed(seed, i + 1)`, so weights are stable under
    /// tenant-count changes (tenant 7 of 1k == tenant 7 of 100k).
    pub seed: u64,
}

impl ScaleSpec {
    /// Panics unless the spec is internally consistent (tier table
    /// non-empty, shares/weights sum to 1, enough tenants, positive
    /// rates, valid curve).
    pub fn assert_valid(&self) {
        assert!(!self.tiers.is_empty(), "{}: no tiers", self.name);
        assert!(
            self.tenants >= self.tiers.len(),
            "{}: {} tenants < {} tiers",
            self.name,
            self.tenants,
            self.tiers.len()
        );
        let share_sum: f64 = self.tiers.iter().map(|t| t.share).sum();
        let weight_sum: f64 =
            self.tiers.iter().map(|t| t.rate_weight).sum();
        assert!(
            (share_sum - 1.0).abs() < 1e-9,
            "{}: tier shares sum to {share_sum}",
            self.name
        );
        assert!(
            (weight_sum - 1.0).abs() < 1e-9,
            "{}: tier rate weights sum to {weight_sum}",
            self.name
        );
        for t in &self.tiers {
            assert!(t.share > 0.0, "{}: tier {} empty share", self.name, t.name);
            assert!(
                t.rate_weight > 0.0,
                "{}: tier {} zero rate weight",
                self.name,
                t.name
            );
        }
        assert!(self.aggregate_hz > 0.0, "{}: aggregate_hz", self.name);
        assert!(
            self.alpha.is_finite() && self.alpha > 0.0,
            "{}: alpha must be positive",
            self.name
        );
        assert!(self.duration_us > 0.0, "{}: duration", self.name);
        self.curve.assert_valid();
    }

    /// Tenants per tier: `round(share * tenants)` clamped to >= 1, the
    /// last tier absorbing the remainder. Deterministic in `tenants`
    /// alone.
    pub fn tier_counts(&self) -> Vec<usize> {
        self.assert_valid();
        let n = self.tiers.len();
        let mut counts = Vec::with_capacity(n);
        let mut assigned = 0usize;
        for (i, t) in self.tiers.iter().enumerate() {
            let remaining_tiers = n - i - 1;
            let c = if i + 1 == n {
                self.tenants - assigned
            } else {
                let want =
                    ((t.share * self.tenants as f64).round() as usize).max(1);
                // Leave at least one tenant for every later tier.
                want.min(self.tenants - assigned - remaining_tiers)
            };
            assert!(c >= 1, "{}: tier {} got no tenants", self.name, t.name);
            counts.push(c);
            assigned += c;
        }
        counts
    }

    /// Tier index of tenant `i` (tiers fill in order: gold tenants are
    /// the lowest indices).
    pub fn tier_of(&self, i: usize) -> usize {
        assert!(i < self.tenants, "tenant {i} out of range");
        let counts = self.tier_counts();
        let mut cum = 0usize;
        for (t, c) in counts.iter().enumerate() {
            cum += c;
            if i < cum {
                return t;
            }
        }
        unreachable!("tier counts do not cover tenant {i}")
    }

    /// Heavy-tailed per-tenant rate weight: `u^(-1/alpha)` with
    /// `u = 1 - next_f64()` in (0, 1] from the tenant's derived seed
    /// (one draw, >= 1, finite).
    fn tenant_weight(&self, i: usize) -> f64 {
        let mut rng =
            Rng::new(crate::coordinator::sweep::derive_seed(self.seed, i as u32 + 1));
        let u = 1.0 - rng.next_f64();
        u.powf(-1.0 / self.alpha)
    }

    /// Per-tenant base rate (Hz): the tier's rate budget
    /// (`aggregate_hz * rate_weight`) split across its tenants in
    /// proportion to their Pareto weights. Summing over all tenants
    /// recovers `aggregate_hz` exactly (up to rounding), whatever
    /// `tenants` is.
    pub fn tenant_rates_hz(&self) -> Vec<f64> {
        let counts = self.tier_counts();
        let weights: Vec<f64> =
            (0..self.tenants).map(|i| self.tenant_weight(i)).collect();
        let mut tier_sums = vec![0.0f64; counts.len()];
        let mut idx = 0usize;
        for (t, c) in counts.iter().enumerate() {
            for _ in 0..*c {
                tier_sums[t] += weights[idx];
                idx += 1;
            }
        }
        let mut rates = Vec::with_capacity(self.tenants);
        let mut idx = 0usize;
        for (t, c) in counts.iter().enumerate() {
            let budget = self.aggregate_hz * self.tiers[t].rate_weight;
            for _ in 0..*c {
                rates.push(budget * weights[idx] / tier_sums[t]);
                idx += 1;
            }
        }
        rates
    }

    /// Compile to a runnable [`ScenarioSpec`]: one
    /// [`Arrival::Modulated`] source per tenant, the curve shared
    /// through a single `Arc`. O(tenants) small structs; no arrival
    /// times are drawn here.
    pub fn compile(&self) -> ScenarioSpec {
        let counts = self.tier_counts();
        let rates = self.tenant_rates_hz();
        let curve = Arc::new(self.curve.clone());
        let mut sources = Vec::with_capacity(self.tenants);
        let mut tier = 0usize;
        let mut left = counts[0];
        for rate_hz in rates {
            while left == 0 {
                tier += 1;
                left = counts[tier];
            }
            left -= 1;
            let t = &self.tiers[tier];
            sources.push(SourceSpec {
                model: t.model.clone(),
                criticality: t.criticality,
                arrival: Arrival::Modulated { rate_hz, curve: curve.clone() },
                deadline_us: t.deadline_us,
            });
        }
        ScenarioSpec {
            name: self.name.clone(),
            sources,
            duration_us: self.duration_us,
            seed: self.seed,
        }
    }
}

/// The standard three-tier scale preset (ISSUE 7): ~1% gold (critical
/// GRU with a deadline), ~9% silver (deadline-tagged SqueezeNet),
/// ~90% bronze (best-effort CIFARNet), 400 Hz aggregate offered load
/// under a diurnal curve with a mid-window 3x flash crowd. The
/// aggregate is independent of `tenants`, so 1k and 100k runs offer
/// the device the same load — only the bookkeeping scales.
pub fn scale_spec(tenants: usize, duration_us: f64) -> ScaleSpec {
    ScaleSpec {
        name: format!("scale-{tenants}t"),
        tenants,
        tiers: vec![
            TierSpec {
                name: "gold".into(),
                share: 0.01,
                model: "gru".into(),
                criticality: Criticality::Critical,
                deadline_us: Some(30_000.0),
                rate_weight: 0.20,
            },
            TierSpec {
                name: "silver".into(),
                share: 0.09,
                model: "squeezenet".into(),
                criticality: Criticality::Normal,
                deadline_us: Some(60_000.0),
                rate_weight: 0.30,
            },
            TierSpec {
                name: "bronze".into(),
                share: 0.90,
                model: "cifarnet".into(),
                criticality: Criticality::Normal,
                deadline_us: None,
                rate_weight: 0.50,
            },
        ],
        aggregate_hz: 400.0,
        alpha: 1.5,
        curve: RateCurve {
            period_us: 250_000.0,
            depth: 0.4,
            flash_at_us: 100_000.0,
            flash_dur_us: 50_000.0,
            flash_boost: 3.0,
        },
        duration_us,
        seed: 0x5CA1E,
    }
}

/// Seeded random-scenario generator: extends the named family with an
/// unbounded stream of valid (2–6 tenant, >= 1 critical, >= 1 normal)
/// scenarios for sweeps. Deterministic per seed.
pub struct ScenarioGen {
    rng: Rng,
    duration_us: f64,
    next_idx: usize,
}

/// Model pool for generated scenarios: the lighter MDTB models, so a
/// generated scenario stays simulable in milliseconds.
const GEN_MODELS: [&str; 4] = ["cifarnet", "squeezenet", "alexnet", "gru"];

impl ScenarioGen {
    /// A generator whose stream of scenarios is fully determined by
    /// `seed`; every generated scenario spans `duration_us`.
    pub fn new(seed: u64, duration_us: f64) -> Self {
        ScenarioGen { rng: Rng::new(seed), duration_us, next_idx: 0 }
    }

    fn random_arrival(&mut self, closed_loop_ok: bool) -> Arrival {
        let kinds = if closed_loop_ok { 5 } else { 4 };
        match self.rng.next_below(kinds) {
            0 => Arrival::Uniform {
                rate_hz: 10.0 + self.rng.next_f64() * 60.0,
            },
            1 => Arrival::Poisson {
                rate_hz: 10.0 + self.rng.next_f64() * 60.0,
            },
            2 => Arrival::Mmpp {
                on_hz: 50.0 + self.rng.next_f64() * 250.0,
                off_hz: self.rng.next_f64() * 10.0,
                mean_on_us: 2_000.0 + self.rng.next_f64() * 8_000.0,
                mean_off_us: 2_000.0 + self.rng.next_f64() * 12_000.0,
            },
            3 => {
                let a = 5.0 + self.rng.next_f64() * 40.0;
                let b = 5.0 + self.rng.next_f64() * 80.0;
                Arrival::Ramp { start_hz: a, end_hz: b }
            }
            _ => Arrival::ClosedLoop {
                clients: 1 + self.rng.next_below(3) as u32,
            },
        }
    }

    /// The next generated scenario.
    pub fn next_scenario(&mut self) -> ScenarioSpec {
        let idx = self.next_idx;
        self.next_idx += 1;
        let tenants = 2 + self.rng.next_below(5) as usize; // 2..=6
        let mut sources = Vec::with_capacity(tenants);
        for i in 0..tenants {
            let model =
                GEN_MODELS[self.rng.next_below(GEN_MODELS.len() as u64) as usize];
            // Tenant 0 is always critical and tenant 1 always normal so
            // every scenario is genuinely mixed-criticality; the rest coin-
            // flip. Critical sources stay open-loop (a hard-real-time task
            // does not self-throttle on completions).
            let critical = match i {
                0 => true,
                1 => false,
                _ => self.rng.next_f64() < 0.4,
            };
            if critical {
                let deadline = if self.rng.next_f64() < 0.7 {
                    Some(10_000.0 + self.rng.next_f64() * 60_000.0)
                } else {
                    None
                };
                let arrival = self.random_arrival(false);
                sources.push(crit(model, arrival, deadline));
            } else {
                let arrival = self.random_arrival(true);
                sources.push(norm(model, arrival));
            }
        }
        ScenarioSpec {
            name: format!("gen-{idx}-{tenants}t"),
            sources,
            duration_us: self.duration_us,
            seed: self.rng.next_u64(),
        }
    }

    /// Generate the next `n` scenarios.
    pub fn take(&mut self, n: usize) -> Vec<ScenarioSpec> {
        (0..n).map(|_| self.next_scenario()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_is_large_mixed_and_uniquely_named() {
        let fam = family(50_000.0);
        assert!(fam.len() >= 8, "family has {}", fam.len());
        let mut names: Vec<&str> =
            fam.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), fam.len(), "duplicate scenario names");
        for sc in &fam {
            assert!(
                (2..=6).contains(&sc.tenants()),
                "{}: {} tenants",
                sc.name,
                sc.tenants()
            );
            assert!(sc.criticals() >= 1, "{}: no critical tenant", sc.name);
            assert!(
                sc.criticals() < sc.tenants(),
                "{}: no normal tenant",
                sc.name
            );
        }
    }

    #[test]
    fn family_builds_runnable_workloads() {
        for sc in family(50_000.0) {
            let wl = sc.build();
            assert_eq!(wl.sources.len(), sc.tenants());
            assert_eq!(wl.name, sc.name);
            for src in &wl.sources {
                assert!(!src.model.kernels.is_empty());
            }
        }
    }

    #[test]
    fn family_exercises_the_new_arrival_processes() {
        let fam = family(50_000.0);
        let has = |pred: fn(&Arrival) -> bool| {
            fam.iter().flat_map(|s| &s.sources).any(|s| pred(&s.arrival))
        };
        assert!(has(|a| matches!(a, Arrival::Mmpp { .. })), "no MMPP");
        assert!(has(|a| matches!(a, Arrival::Ramp { .. })), "no ramp");
        assert!(has(|a| matches!(a, Arrival::Replay { .. })), "no replay");
        assert!(
            fam.iter()
                .flat_map(|s| &s.sources)
                .any(|s| s.deadline_us.is_some()),
            "no deadline-tagged source"
        );
    }

    #[test]
    fn by_name_resolves_and_golden_cells_exist() {
        assert!(by_name("duo-burst", 1e5).is_some());
        assert!(by_name("DUO-BURST", 1e5).is_some());
        assert!(by_name("mdtb-a", 1e5).is_none());
        for (sc, _sched) in GOLDEN_CELLS {
            assert!(
                by_name(sc, GOLDEN_DURATION_US).is_some(),
                "golden cell references unknown scenario {sc}"
            );
        }
        assert_eq!(
            golden_file_name("duo-burst", "ib"),
            "duo-burst__ib.trace.json"
        );
    }

    #[test]
    fn isolation_golden_cells_exist_and_slug_is_path_safe() {
        for (sc, sched) in ISOLATION_GOLDEN_CELLS {
            assert!(
                by_name(sc, GOLDEN_DURATION_US).is_some(),
                "isolation golden cell references unknown scenario {sc}"
            );
            assert!(ISOLATION_GOLDEN_SCHEDULERS.contains(&sched),
                    "isolation golden cell names unpinned scheduler {sched}");
        }
        for sched in ISOLATION_GOLDEN_SCHEDULERS {
            assert!(crate::coordinator::is_scheduler_name(sched),
                    "pinned isolation scheduler {sched} does not resolve");
            let slug = scheduler_file_slug(sched);
            assert!(!slug.contains(['/', ':', '+']), "unsanitized {slug}");
        }
        // Slug is identity on the paper schedulers (golden names stable).
        for sched in crate::coordinator::SCHEDULERS {
            assert_eq!(scheduler_file_slug(sched), sched);
        }
        assert_eq!(
            golden_file_name("duo-burst", "isolation:70/30+spill"),
            "duo-burst__isolation-70-30-spill.trace.json"
        );
    }

    #[test]
    fn device_golden_cells_name_real_platforms_and_scenarios() {
        use crate::gpu::spec::GpuSpec;
        for p in DEVICE_GOLDEN_PLATFORMS {
            let spec = GpuSpec::by_name(p)
                .unwrap_or_else(|| panic!("unknown device platform {p}"));
            assert_eq!(spec.name, p, "device goldens need canonical names");
            assert_ne!(p, GOLDEN_PLATFORM,
                       "device goldens must extend, not duplicate, the \
                        main set");
        }
        for sc in DEVICE_GOLDEN_SCENARIOS {
            assert!(by_name(sc, GOLDEN_DURATION_US).is_some(),
                    "device golden references unknown scenario {sc}");
        }
        assert_eq!(
            device_golden_file_name("tx2", "duo-burst", "ib"),
            "tx2__duo-burst__ib.trace.json"
        );
    }

    #[test]
    fn generator_is_deterministic_and_valid() {
        let a = ScenarioGen::new(7, 40_000.0).take(12);
        let b = ScenarioGen::new(7, 40_000.0).take(12);
        assert_eq!(a.len(), 12);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.tenants(), y.tenants());
        }
        for sc in &a {
            assert!((2..=6).contains(&sc.tenants()), "{}", sc.name);
            assert!(sc.criticals() >= 1 && sc.criticals() < sc.tenants());
            sc.build(); // all model names resolve
            for s in &sc.sources {
                if s.criticality == Criticality::Critical {
                    assert!(
                        !s.arrival.is_closed_loop(),
                        "{}: closed-loop critical",
                        sc.name
                    );
                }
            }
        }
        let c = ScenarioGen::new(8, 40_000.0).take(12);
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.seed != y.seed),
            "different gen seeds produced identical scenarios"
        );
    }

    #[test]
    fn mdtb_scenarios_match_their_workload_specs() {
        let scens = mdtb_scenarios(1e5);
        let specs = crate::workloads::mdtb::all(1e5);
        assert_eq!(scens.len(), 4);
        for (sc, spec) in scens.iter().zip(&specs) {
            assert_eq!(sc.name, spec.name);
            assert_eq!(sc.seed, spec.seed);
            let a = sc.build();
            let b = spec.build();
            assert_eq!(a.sources.len(), b.sources.len());
            for (x, y) in a.sources.iter().zip(&b.sources) {
                assert_eq!(x.model.name, y.model.name);
                assert_eq!(x.criticality, y.criticality);
                assert_eq!(x.deadline_us, y.deadline_us);
            }
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.duration_us, b.duration_us);
        }
    }

    #[test]
    fn scale_spec_tiers_cover_population_and_fix_aggregate() {
        for tenants in [10, 1_000, 10_000] {
            let spec = scale_spec(tenants, 100_000.0);
            let counts = spec.tier_counts();
            assert_eq!(counts.len(), 3);
            assert_eq!(counts.iter().sum::<usize>(), tenants);
            assert!(counts.iter().all(|c| *c >= 1), "{counts:?}");
            let rates = spec.tenant_rates_hz();
            assert_eq!(rates.len(), tenants);
            assert!(rates.iter().all(|r| *r > 0.0 && r.is_finite()));
            let total: f64 = rates.iter().sum();
            assert!(
                (total - spec.aggregate_hz).abs() < 1e-6,
                "{tenants} tenants: aggregate {total}"
            );
        }
    }

    #[test]
    fn scale_spec_weights_are_stable_under_tenant_count() {
        // Tenant i's Pareto weight comes from derive_seed(seed, i+1),
        // so growing the population must not change existing tenants'
        // weights (only the tier normalization redistributes rates).
        let small = scale_spec(100, 100_000.0);
        let large = scale_spec(200, 100_000.0);
        for i in [0usize, 1, 7, 42, 99] {
            let a = small.tenant_weight(i);
            let b = large.tenant_weight(i);
            assert_eq!(a.to_bits(), b.to_bits(), "tenant {i}: {a} vs {b}");
            assert!(a >= 1.0 && a.is_finite(), "tenant {i}: weight {a}");
        }
    }

    #[test]
    fn scale_spec_rates_are_heavy_tailed() {
        let spec = scale_spec(10_000, 100_000.0);
        let mut rates = spec.tenant_rates_hz();
        rates.sort_by(f64::total_cmp);
        let total: f64 = rates.iter().sum();
        let top1: f64 = rates[rates.len() - 100..].iter().sum();
        // With alpha = 1.5, the top 1% of tenants should carry far
        // more than 1% of the load.
        assert!(top1 / total > 0.05, "top-1% share {}", top1 / total);
    }

    #[test]
    fn scale_spec_compiles_to_lazy_modulated_sources() {
        let spec = scale_spec(1_000, 100_000.0);
        let sc = spec.compile();
        assert_eq!(sc.tenants(), 1_000);
        assert_eq!(sc.seed, spec.seed);
        let mut crits = 0usize;
        for (i, s) in sc.sources.iter().enumerate() {
            match &s.arrival {
                Arrival::Modulated { rate_hz, curve } => {
                    assert!(*rate_hz > 0.0);
                    curve.assert_valid();
                }
                other => panic!("tenant {i}: non-modulated {other:?}"),
            }
            if s.criticality == Criticality::Critical {
                crits += 1;
                assert!(s.deadline_us.is_some());
            }
        }
        assert_eq!(crits, spec.tier_counts()[0]);
        // The shared curve really is shared: one Arc, not N copies.
        let first = match &sc.sources[0].arrival {
            Arrival::Modulated { curve, .. } => Arc::as_ptr(curve),
            _ => unreachable!(),
        };
        for s in &sc.sources {
            if let Arrival::Modulated { curve, .. } = &s.arrival {
                assert_eq!(Arc::as_ptr(curve), first);
            }
        }
        // Tenant labels stay well-formed at scale.
        assert!(sc.tenant_label(0).starts_with("t0-gru-critical"));
    }

    #[test]
    fn recorded_trace_is_sorted_after_replay_wrap() {
        let times = recorded_trace(100_000.0, 50.0, 1_500.0, 42);
        assert_eq!(times.len(), 5);
        let a = Arrival::replay(times);
        let s = a.schedule(100_000.0, &mut Rng::new(1));
        for w in s.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert!(s.iter().all(|t| *t >= 0.0));
    }
}
