//! MDTB — the Mixed-critical DNN Task Benchmark (paper Table 2).
//!
//! Four workloads, each one critical source + one normal source:
//!
//! | MDTB | critical (arrival)            | normal (arrival)        |
//! |------|-------------------------------|-------------------------|
//! | A    | AlexNet    (closed-loop)      | CifarNet   (closed-loop)|
//! | B    | SqueezeNet (uniform 10 req/s) | AlexNet    (closed-loop)|
//! | C    | GRU        (Poisson 10 req/s) | ResNet     (closed-loop)|
//! | D    | LSTM       (uniform 10 req/s) | SqueezeNet (closed-loop)|

use std::sync::Arc;


use crate::gpu::kernel::Criticality;
use crate::workloads::arrival::Arrival;
use crate::workloads::models::{self, ModelRef};

/// One request source: a model issued with some arrival process at a
/// criticality level.
#[derive(Debug, Clone)]
pub struct Source {
    /// The model this source requests.
    pub model: ModelRef,
    /// How requests arrive.
    pub arrival: Arrival,
    /// Task class of every request from this source.
    pub criticality: Criticality,
    /// Optional end-to-end deadline (us). Completions later than this are
    /// counted in `RunStats::deadline_misses_*`; `None` means best-effort
    /// latency only (the MDTB default — Table 2 specifies no deadlines).
    pub deadline_us: Option<f64>,
}

/// A complete benchmark workload.
#[derive(Debug, Clone)]
pub struct Workload {
    /// Workload name (report key).
    pub name: String,
    /// The request sources (tenants).
    pub sources: Vec<Source>,
    /// Simulated duration over which arrivals are generated (us).
    pub duration_us: f64,
    /// RNG seed for stochastic arrivals.
    pub seed: u64,
}

/// Serializable description (for configs / CLI).
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Workload name (e.g. "MDTB-A").
    pub name: String,
    /// Critical source's model name.
    pub critical_model: String,
    /// Critical source's arrival process.
    pub critical_arrival: Arrival,
    /// Normal source's model name.
    pub normal_model: String,
    /// Normal source's arrival process.
    pub normal_arrival: Arrival,
    /// Arrival-generation window (us).
    pub duration_us: f64,
    /// RNG seed for stochastic arrivals.
    pub seed: u64,
}

impl WorkloadSpec {
    /// Resolve model names and materialize the runnable [`Workload`].
    /// Panics on an unknown model name.
    pub fn build(&self) -> Workload {
        let critical = models::by_name(&self.critical_model)
            .unwrap_or_else(|| panic!("unknown model {}", self.critical_model));
        let normal = models::by_name(&self.normal_model)
            .unwrap_or_else(|| panic!("unknown model {}", self.normal_model));
        Workload {
            name: self.name.clone(),
            sources: vec![
                Source {
                    model: Arc::new(critical),
                    arrival: self.critical_arrival.clone(),
                    criticality: Criticality::Critical,
                    deadline_us: None,
                },
                Source {
                    model: Arc::new(normal),
                    arrival: self.normal_arrival.clone(),
                    criticality: Criticality::Normal,
                    deadline_us: None,
                },
            ],
            duration_us: self.duration_us,
            seed: self.seed,
        }
    }
}

/// MDTB A: closed-loop AlexNet critical vs closed-loop CifarNet normal.
pub fn mdtb_a(duration_us: f64) -> WorkloadSpec {
    WorkloadSpec {
        name: "MDTB-A".into(),
        critical_model: "alexnet".into(),
        critical_arrival: Arrival::ClosedLoop { clients: 1 },
        normal_model: "cifarnet".into(),
        normal_arrival: Arrival::ClosedLoop { clients: 3 },
        duration_us,
        seed: 0xA,
    }
}

/// MDTB B: uniform-10Hz SqueezeNet critical vs closed-loop AlexNet normal.
pub fn mdtb_b(duration_us: f64) -> WorkloadSpec {
    WorkloadSpec {
        name: "MDTB-B".into(),
        critical_model: "squeezenet".into(),
        critical_arrival: Arrival::Uniform { rate_hz: 10.0 },
        normal_model: "alexnet".into(),
        normal_arrival: Arrival::ClosedLoop { clients: 3 },
        duration_us,
        seed: 0xB,
    }
}

/// MDTB C: Poisson-10Hz GRU critical vs closed-loop ResNet normal.
pub fn mdtb_c(duration_us: f64) -> WorkloadSpec {
    WorkloadSpec {
        name: "MDTB-C".into(),
        critical_model: "gru".into(),
        critical_arrival: Arrival::Poisson { rate_hz: 10.0 },
        normal_model: "resnet".into(),
        normal_arrival: Arrival::ClosedLoop { clients: 3 },
        duration_us,
        seed: 0xC,
    }
}

/// MDTB D: uniform-10Hz LSTM critical vs closed-loop SqueezeNet normal.
pub fn mdtb_d(duration_us: f64) -> WorkloadSpec {
    WorkloadSpec {
        name: "MDTB-D".into(),
        critical_model: "lstm".into(),
        critical_arrival: Arrival::Uniform { rate_hz: 10.0 },
        normal_model: "squeezenet".into(),
        normal_arrival: Arrival::ClosedLoop { clients: 3 },
        duration_us,
        seed: 0xD,
    }
}

/// All four Table 2 workloads.
pub fn all(duration_us: f64) -> Vec<WorkloadSpec> {
    vec![mdtb_a(duration_us), mdtb_b(duration_us), mdtb_c(duration_us),
         mdtb_d(duration_us)]
}

/// Look up an MDTB workload by letter or full name ("A" / "MDTB-A").
pub fn by_name(name: &str, duration_us: f64) -> Option<WorkloadSpec> {
    match name.to_ascii_uppercase().as_str() {
        "A" | "MDTB-A" => Some(mdtb_a(duration_us)),
        "B" | "MDTB-B" => Some(mdtb_b(duration_us)),
        "C" | "MDTB-C" => Some(mdtb_c(duration_us)),
        "D" | "MDTB-D" => Some(mdtb_d(duration_us)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_composition() {
        let a = mdtb_a(1e6).build();
        assert_eq!(a.sources[0].model.name, "alexnet");
        assert_eq!(a.sources[0].criticality, Criticality::Critical);
        assert_eq!(a.sources[1].model.name, "cifarnet");
        assert!(a.sources[1].arrival.is_closed_loop());

        let c = mdtb_c(1e6).build();
        assert_eq!(c.sources[0].model.name, "gru");
        assert!(matches!(c.sources[0].arrival, Arrival::Poisson { rate_hz }
            if (rate_hz - 10.0).abs() < 1e-9));
    }

    #[test]
    fn lookup_by_letter() {
        assert!(by_name("a", 1e6).is_some());
        assert!(by_name("MDTB-D", 1e6).is_some());
        assert!(by_name("E", 1e6).is_none());
    }

    #[test]
    fn all_four_present() {
        let v = all(1e6);
        assert_eq!(v.len(), 4);
        let names: Vec<_> = v.iter().map(|w| w.name.as_str()).collect();
        assert_eq!(names, ["MDTB-A", "MDTB-B", "MDTB-C", "MDTB-D"]);
    }
}
