//! Request arrival processes (paper §8.1.2 plus the scenario-harness
//! extensions): uniform (fixed frequency), Poisson (event-driven),
//! closed-loop (always one outstanding request), on/off MMPP bursts,
//! linear rate ramps, trace replay of a recorded arrival list, and
//! rate-modulated Poisson (diurnal curve + flash crowd, ISSUE 7).
//!
//! Every process has two equivalent forms: [`Arrival::schedule`]
//! materializes the arrival `Vec` up front (the small-tenant paths), and
//! [`Arrival::stream`] yields the *same* arrivals lazily, one at a time,
//! drawing from the RNG in the exact same order — the 100k-tenant scale
//! path keeps one pending arrival per tenant instead of a pre-drawn
//! vector per tenant. The draw-for-draw equivalence is pinned by the
//! `stream_matches_schedule_*` tests below.

use std::sync::Arc;

use crate::workloads::rng::Rng;

/// Deterministic rate-modulation curve for [`Arrival::Modulated`]
/// (ISSUE 7): a sinusoidal "diurnal" factor plus one optional
/// multiplicative flash-crowd window.
///
/// The instantaneous rate at time `t` is
/// `rate_hz * (1 + depth * sin(2π t / period_us)) * boost(t)` where
/// `boost(t)` is `flash_boost` inside
/// `[flash_at_us, flash_at_us + flash_dur_us)` and 1 elsewhere.
#[derive(Debug, Clone, PartialEq)]
pub struct RateCurve {
    /// Diurnal period (us); must be positive.
    pub period_us: f64,
    /// Diurnal modulation depth in [0, 1]: 0 = flat, 1 = rate swings
    /// between 0 and 2× the base.
    pub depth: f64,
    /// Flash-crowd start (us, ≥ 0).
    pub flash_at_us: f64,
    /// Flash-crowd duration (us, ≥ 0; 0 disables the flash).
    pub flash_dur_us: f64,
    /// Multiplicative rate boost inside the flash window (≥ 1).
    pub flash_boost: f64,
}

impl RateCurve {
    /// A flat curve (factor 1 everywhere) — Modulated degenerates to
    /// plain Poisson statistics.
    pub fn flat() -> RateCurve {
        RateCurve {
            period_us: 1.0,
            depth: 0.0,
            flash_at_us: 0.0,
            flash_dur_us: 0.0,
            flash_boost: 1.0,
        }
    }

    /// Panics unless every field is finite and within its documented
    /// range (thinning correctness depends on these bounds).
    pub fn assert_valid(&self) {
        assert!(self.period_us.is_finite() && self.period_us > 0.0,
                "RateCurve.period_us must be positive");
        assert!((0.0..=1.0).contains(&self.depth),
                "RateCurve.depth must be in [0, 1]");
        assert!(self.flash_at_us.is_finite() && self.flash_at_us >= 0.0,
                "RateCurve.flash_at_us must be non-negative");
        assert!(self.flash_dur_us.is_finite() && self.flash_dur_us >= 0.0,
                "RateCurve.flash_dur_us must be non-negative");
        assert!(self.flash_boost.is_finite() && self.flash_boost >= 1.0,
                "RateCurve.flash_boost must be >= 1");
    }

    /// True when `t` falls inside the flash-crowd window.
    fn in_flash(&self, t: f64) -> bool {
        t >= self.flash_at_us && t < self.flash_at_us + self.flash_dur_us
    }

    /// Instantaneous modulation factor at `t` (≥ 0).
    pub fn factor(&self, t: f64) -> f64 {
        let diurnal = 1.0
            + self.depth
                * (2.0 * std::f64::consts::PI * t / self.period_us).sin();
        let boost = if self.in_flash(t) { self.flash_boost } else { 1.0 };
        diurnal * boost
    }

    /// Piecewise-constant upper bound on [`factor`](Self::factor) over
    /// the envelope segment containing `t` — the thinning envelope.
    fn envelope_factor(&self, t: f64) -> f64 {
        let boost = if self.in_flash(t) { self.flash_boost } else { 1.0 };
        (1.0 + self.depth) * boost
    }

    /// The next time after `t` where the envelope changes (flash start
    /// or end), or +∞ when none remains.
    fn next_envelope_boundary(&self, t: f64) -> f64 {
        if t < self.flash_at_us {
            self.flash_at_us
        } else if self.in_flash(t) {
            self.flash_at_us + self.flash_dur_us
        } else {
            f64::INFINITY
        }
    }

    /// Window-averaged modulation factor over `[0, duration_us)` — the
    /// diurnal term averages to ~1 over whole periods, the flash window
    /// contributes its overlap. Used by
    /// [`Arrival::nominal_rate_hz`].
    pub fn mean_factor(&self, duration_us: f64) -> f64 {
        if duration_us <= 0.0 {
            return 1.0;
        }
        let flash_end =
            (self.flash_at_us + self.flash_dur_us).min(duration_us);
        let overlap = (flash_end - self.flash_at_us).max(0.0);
        1.0 + (self.flash_boost - 1.0) * overlap / duration_us
    }
}

/// How a client issues inference requests.
#[derive(Debug, Clone, PartialEq)]
pub enum Arrival {
    /// Fixed frequency in requests/second (e.g. pose estimation).
    Uniform { rate_hz: f64 },
    /// Poisson arrivals (event-driven apps, e.g. obstacle detection).
    Poisson { rate_hz: f64 },
    /// `clients` independent closed-loop clients: each sends its next
    /// request the moment its previous one completes (DISB closed-loop;
    /// the paper's MDTB drives normal tasks with several such clients to
    /// keep the best-effort queue non-empty).
    ClosedLoop { clients: u32 },
    /// Two-state Markov-modulated Poisson process (on/off burst model,
    /// the DeepRT/EdgeServing-style bursty tenant): Poisson arrivals at
    /// `on_hz` during exponentially-distributed "on" sojourns (mean
    /// `mean_on_us`) and at `off_hz` during "off" sojourns (mean
    /// `mean_off_us`). The process starts in the "on" state.
    Mmpp {
        on_hz: f64,
        off_hz: f64,
        mean_on_us: f64,
        mean_off_us: f64,
    },
    /// Deterministic linear rate ramp from `start_hz` at t=0 to `end_hz`
    /// at the end of the schedule window: arrival k lands where the
    /// integrated rate reaches k (inverse cumulative intensity), so the
    /// first arrival is at t=0 like [`Arrival::Uniform`].
    Ramp { start_hz: f64, end_hz: f64 },
    /// Replay of a recorded arrival-time list (us, ascending). Arrivals
    /// at or beyond the schedule window are dropped.
    Replay { times: Arc<Vec<f64>> },
    /// Inhomogeneous Poisson with a deterministic [`RateCurve`]
    /// (diurnal modulation + flash crowd), sampled by thinning against a
    /// piecewise-constant envelope (ISSUE 7 scale tenants). `rate_hz` is
    /// the un-modulated base rate; the curve is shared (`Arc`) across a
    /// whole tenant tier.
    Modulated {
        /// Base rate (Hz) before modulation.
        rate_hz: f64,
        /// The shared modulation curve.
        curve: Arc<RateCurve>,
    },
}

impl Arrival {
    /// Wrap a recorded arrival list (sorted here) for replay.
    ///
    /// NaN-safe (ISSUE 7 bugfix): sorts with [`f64::total_cmp`] — the
    /// old `partial_cmp(..).unwrap()` panicked on NaN input. A NaN time
    /// sorts after +∞ and is then dropped by [`schedule`](Self::schedule)
    /// (`NaN < duration` is false), so it can never reach the arrival
    /// queue.
    pub fn replay(mut times: Vec<f64>) -> Arrival {
        times.sort_by(f64::total_cmp);
        Arrival::Replay { times: Arc::new(times) }
    }

    /// Pre-generate open-loop arrival times (us) within `[0, duration_us)`.
    /// Closed-loop yields a single arrival at t=0 per client; subsequent
    /// arrivals are generated by the driver on completion.
    pub fn schedule(&self, duration_us: f64, rng: &mut Rng) -> Vec<f64> {
        match self {
            Arrival::Uniform { rate_hz } => {
                assert!(*rate_hz > 0.0);
                let period = 1e6 / rate_hz;
                let mut out = Vec::new();
                let mut t = 0.0;
                while t < duration_us {
                    out.push(t);
                    t += period;
                }
                out
            }
            Arrival::Poisson { rate_hz } => {
                assert!(*rate_hz > 0.0);
                let lambda = rate_hz / 1e6; // events per us
                let mut out = Vec::new();
                let mut t = rng.next_exp(lambda);
                while t < duration_us {
                    out.push(t);
                    t += rng.next_exp(lambda);
                }
                out
            }
            Arrival::ClosedLoop { clients } => vec![0.0; *clients as usize],
            Arrival::Mmpp { on_hz, off_hz, mean_on_us, mean_off_us } => {
                assert!(*on_hz >= 0.0 && *off_hz >= 0.0);
                assert!(on_hz + off_hz > 0.0);
                assert!(*mean_on_us > 0.0 && *mean_off_us > 0.0);
                let mut out = Vec::new();
                let mut t = 0.0;
                let mut on = true;
                let mut t_switch = rng.next_exp(1.0 / mean_on_us);
                while t < duration_us {
                    let hz = if on { *on_hz } else { *off_hz };
                    let rate = hz / 1e6;
                    let dt = if rate > 0.0 {
                        rng.next_exp(rate)
                    } else {
                        f64::INFINITY
                    };
                    // Memorylessness makes re-drawing the arrival gap after
                    // a state switch statistically exact.
                    if t + dt < t_switch {
                        t += dt;
                        if t < duration_us {
                            out.push(t);
                        }
                    } else {
                        t = t_switch;
                        on = !on;
                        let mean = if on { mean_on_us } else { mean_off_us };
                        t_switch = t + rng.next_exp(1.0 / mean);
                    }
                }
                out
            }
            Arrival::Ramp { start_hz, end_hz } => {
                assert!(*start_hz >= 0.0 && *end_hz >= 0.0);
                assert!(start_hz + end_hz > 0.0);
                assert!(duration_us > 0.0);
                let r0 = start_hz / 1e6;
                let r1 = end_hz / 1e6;
                let slope = (r1 - r0) / duration_us;
                let mut out = Vec::new();
                if slope.abs() < 1e-18 {
                    let period = 1.0 / r0;
                    let mut t = 0.0;
                    while t < duration_us {
                        out.push(t);
                        t += period;
                    }
                } else {
                    // Invert the cumulative intensity
                    // L(t) = r0*t + slope*t^2/2 at L(t) = k.
                    let mut k = 0u64;
                    loop {
                        let disc = r0 * r0 + 2.0 * slope * k as f64;
                        if disc < 0.0 {
                            break; // decreasing ramp ran out of intensity
                        }
                        let t = (disc.sqrt() - r0) / slope;
                        if t >= duration_us {
                            break;
                        }
                        out.push(t);
                        k += 1;
                    }
                }
                out
            }
            Arrival::Replay { times } => {
                times.iter().copied().filter(|t| *t < duration_us).collect()
            }
            Arrival::Modulated { .. } => {
                // Single implementation: the materialized schedule IS the
                // collected stream, so the two forms cannot diverge.
                let mut s = self.stream(duration_us);
                let mut out = Vec::new();
                while let Some(t) = s.next(rng) {
                    out.push(t);
                }
                out
            }
        }
    }

    /// Lazy form of [`schedule`](Self::schedule): an iterator-style
    /// stream yielding the same arrivals in the same order, drawing from
    /// the RNG in the exact same sequence (pinned by the
    /// `stream_matches_schedule_*` tests). The scale path holds one
    /// stream per tenant — O(1) memory — instead of a pre-drawn `Vec`.
    ///
    /// Performs the same argument validation as `schedule` (panics on
    /// the same inputs). After the first `None`, further calls keep
    /// returning `None` without consuming RNG draws beyond what
    /// `schedule` would have drawn.
    pub fn stream(&self, duration_us: f64) -> ArrivalStream {
        match self {
            Arrival::Uniform { rate_hz } => {
                assert!(*rate_hz > 0.0);
                ArrivalStream::Periodic {
                    period: 1e6 / rate_hz,
                    next: 0.0,
                    end: duration_us,
                }
            }
            Arrival::Poisson { rate_hz } => {
                assert!(*rate_hz > 0.0);
                ArrivalStream::Poisson {
                    lambda: rate_hz / 1e6,
                    t: 0.0,
                    started: false,
                    end: duration_us,
                }
            }
            Arrival::ClosedLoop { clients } => {
                ArrivalStream::Seeds { remaining: *clients }
            }
            Arrival::Mmpp { on_hz, off_hz, mean_on_us, mean_off_us } => {
                assert!(*on_hz >= 0.0 && *off_hz >= 0.0);
                assert!(on_hz + off_hz > 0.0);
                assert!(*mean_on_us > 0.0 && *mean_off_us > 0.0);
                ArrivalStream::Mmpp {
                    on_hz: *on_hz,
                    off_hz: *off_hz,
                    mean_on_us: *mean_on_us,
                    mean_off_us: *mean_off_us,
                    t: 0.0,
                    on: true,
                    t_switch: 0.0,
                    started: false,
                    end: duration_us,
                }
            }
            Arrival::Ramp { start_hz, end_hz } => {
                assert!(*start_hz >= 0.0 && *end_hz >= 0.0);
                assert!(start_hz + end_hz > 0.0);
                assert!(duration_us > 0.0);
                let r0 = start_hz / 1e6;
                let r1 = end_hz / 1e6;
                let slope = (r1 - r0) / duration_us;
                if slope.abs() < 1e-18 {
                    ArrivalStream::Periodic {
                        period: 1.0 / r0,
                        next: 0.0,
                        end: duration_us,
                    }
                } else {
                    ArrivalStream::Ramp {
                        r0,
                        slope,
                        k: 0,
                        end: duration_us,
                    }
                }
            }
            Arrival::Replay { times } => ArrivalStream::Replay {
                times: times.clone(),
                idx: 0,
                end: duration_us,
            },
            Arrival::Modulated { rate_hz, curve } => {
                assert!(*rate_hz > 0.0);
                curve.assert_valid();
                ArrivalStream::Modulated {
                    rate: rate_hz / 1e6,
                    curve: curve.clone(),
                    t: 0.0,
                    end: duration_us,
                }
            }
        }
    }

    /// Whether this process regenerates arrivals on completion (the
    /// driver and the serving loop treat these sources specially).
    pub fn is_closed_loop(&self) -> bool {
        matches!(self, Arrival::ClosedLoop { .. })
    }

    /// Nominal mean arrival rate (Hz) where one is defined: the long-run
    /// average for stochastic processes, the window average for ramps,
    /// the un-modulated base rate for [`Arrival::Modulated`] (the
    /// diurnal term averages to the base over whole periods; flash
    /// windows are transient by construction). `None` for closed-loop
    /// (rate is completion-driven) and replay (rate is whatever the
    /// recording contains).
    pub fn nominal_rate_hz(&self) -> Option<f64> {
        match self {
            Arrival::Uniform { rate_hz }
            | Arrival::Poisson { rate_hz }
            | Arrival::Modulated { rate_hz, .. } => Some(*rate_hz),
            Arrival::Mmpp { on_hz, off_hz, mean_on_us, mean_off_us } => Some(
                (on_hz * mean_on_us + off_hz * mean_off_us)
                    / (mean_on_us + mean_off_us),
            ),
            Arrival::Ramp { start_hz, end_hz } => {
                Some(0.5 * (start_hz + end_hz))
            }
            Arrival::ClosedLoop { .. } | Arrival::Replay { .. } => None,
        }
    }
}

/// Lazy arrival generator produced by [`Arrival::stream`]. Each call to
/// [`next`](Self::next) yields one arrival time (us) or `None` when the
/// window `[0, end)` is exhausted, drawing from the caller's RNG in the
/// exact sequence [`Arrival::schedule`] would — so a stream and a
/// pre-drawn schedule over the same seed are interchangeable draw for
/// draw (pinned by the `stream_matches_schedule_*` tests). A stream is
/// a few machine words (plus a shared `Arc` for replay/modulated);
/// `next` never allocates.
#[derive(Debug, Clone)]
pub enum ArrivalStream {
    /// Fixed-period arrivals starting at t=0 ([`Arrival::Uniform`] and
    /// flat [`Arrival::Ramp`]).
    Periodic { period: f64, next: f64, end: f64 },
    /// Homogeneous Poisson ([`Arrival::Poisson`]); `lambda` is events
    /// per us. `started` distinguishes the first absolute draw from the
    /// subsequent incremental ones.
    Poisson { lambda: f64, t: f64, started: bool, end: f64 },
    /// Closed-loop seed arrivals: one t=0 arrival per client, ignoring
    /// the window (exactly `schedule`'s `vec![0.0; clients]`).
    Seeds { remaining: u32 },
    /// Two-state MMPP ([`Arrival::Mmpp`]); the first call draws the
    /// initial on-sojourn length, matching `schedule`'s draw order.
    Mmpp {
        on_hz: f64,
        off_hz: f64,
        mean_on_us: f64,
        mean_off_us: f64,
        t: f64,
        on: bool,
        t_switch: f64,
        started: bool,
        end: f64,
    },
    /// Non-flat linear ramp: arrival `k` inverts the cumulative
    /// intensity `L(t) = r0*t + slope*t^2/2` at `L(t) = k`.
    Ramp { r0: f64, slope: f64, k: u64, end: f64 },
    /// Recorded-trace replay; entries at or beyond `end` are skipped
    /// (filter semantics, not truncation — the recording need not be
    /// fully in-window even though [`Arrival::replay`] sorts it).
    Replay { times: Arc<Vec<f64>>, idx: usize, end: f64 },
    /// Inhomogeneous Poisson by thinning ([`Arrival::Modulated`]);
    /// `rate` is the base rate in events per us. Candidates are drawn
    /// against the piecewise-constant envelope and accepted with
    /// probability `factor(t) / envelope_factor(t)`; crossing an
    /// envelope boundary restarts the exponential draw there
    /// (memorylessness makes this statistically exact).
    Modulated { rate: f64, curve: Arc<RateCurve>, t: f64, end: f64 },
}

impl ArrivalStream {
    /// Yield the next arrival time (us), or `None` when the window is
    /// exhausted. After the first `None`, further calls return `None`
    /// without drawing from the RNG.
    pub fn next(&mut self, rng: &mut Rng) -> Option<f64> {
        match self {
            ArrivalStream::Periodic { period, next, end } => {
                if *next < *end {
                    let t = *next;
                    *next += *period;
                    Some(t)
                } else {
                    None
                }
            }
            ArrivalStream::Poisson { lambda, t, started, end } => {
                let nt = if !*started {
                    *started = true;
                    rng.next_exp(*lambda)
                } else {
                    if *t >= *end {
                        return None; // exhausted on a previous call
                    }
                    *t + rng.next_exp(*lambda)
                };
                *t = nt;
                if nt < *end { Some(nt) } else { None }
            }
            ArrivalStream::Seeds { remaining } => {
                if *remaining > 0 {
                    *remaining -= 1;
                    Some(0.0)
                } else {
                    None
                }
            }
            ArrivalStream::Mmpp {
                on_hz,
                off_hz,
                mean_on_us,
                mean_off_us,
                t,
                on,
                t_switch,
                started,
                end,
            } => {
                if !*started {
                    *started = true;
                    *t_switch = rng.next_exp(1.0 / *mean_on_us);
                }
                loop {
                    if *t >= *end {
                        return None;
                    }
                    let hz = if *on { *on_hz } else { *off_hz };
                    let rate = hz / 1e6;
                    let dt = if rate > 0.0 {
                        rng.next_exp(rate)
                    } else {
                        f64::INFINITY
                    };
                    // Memorylessness makes re-drawing the arrival gap
                    // after a state switch statistically exact.
                    if *t + dt < *t_switch {
                        *t += dt;
                        if *t < *end {
                            return Some(*t);
                        }
                    } else {
                        *t = *t_switch;
                        *on = !*on;
                        let mean =
                            if *on { *mean_on_us } else { *mean_off_us };
                        *t_switch = *t + rng.next_exp(1.0 / mean);
                    }
                }
            }
            ArrivalStream::Ramp { r0, slope, k, end } => {
                let disc = *r0 * *r0 + 2.0 * *slope * *k as f64;
                if disc < 0.0 {
                    return None; // decreasing ramp ran out of intensity
                }
                let t = (disc.sqrt() - *r0) / *slope;
                if t >= *end {
                    return None;
                }
                *k += 1;
                Some(t)
            }
            ArrivalStream::Replay { times, idx, end } => {
                while *idx < times.len() {
                    let t = times[*idx];
                    *idx += 1;
                    if t < *end {
                        return Some(t);
                    }
                }
                None
            }
            ArrivalStream::Modulated { rate, curve, t, end } => {
                loop {
                    if *t >= *end {
                        return None;
                    }
                    let env = curve.envelope_factor(*t);
                    let boundary = curve.next_envelope_boundary(*t);
                    let nt = *t + rng.next_exp(*rate * env);
                    if boundary.is_finite() && nt >= boundary {
                        // Envelope changes before the candidate lands:
                        // restart the draw at the boundary.
                        *t = boundary;
                        continue;
                    }
                    *t = nt;
                    if nt >= *end {
                        return None;
                    }
                    if rng.next_f64() * env < curve.factor(nt) {
                        return Some(nt);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Empirical mean inter-arrival time (us) of a schedule.
    fn mean_interarrival(s: &[f64]) -> f64 {
        assert!(s.len() >= 2, "need at least two arrivals, got {}", s.len());
        (s.last().unwrap() - s.first().unwrap()) / (s.len() - 1) as f64
    }

    fn assert_sorted(s: &[f64]) {
        for w in s.windows(2) {
            assert!(w[1] >= w[0], "unsorted: {} then {}", w[0], w[1]);
        }
    }

    #[test]
    fn uniform_is_periodic() {
        let mut rng = Rng::new(1);
        let s = Arrival::Uniform { rate_hz: 10.0 }.schedule(1e6, &mut rng);
        assert_eq!(s.len(), 10);
        for (i, t) in s.iter().enumerate() {
            assert!((t - i as f64 * 1e5).abs() < 1e-9);
        }
    }

    #[test]
    fn poisson_rate_approximately_holds() {
        let mut rng = Rng::new(2);
        let s = Arrival::Poisson { rate_hz: 100.0 }.schedule(100e6, &mut rng);
        // 100 Hz over 100 s -> ~10_000 arrivals (+-5%).
        assert!((9_500..=10_500).contains(&s.len()), "{}", s.len());
        // Strictly increasing.
        for w in s.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn poisson_deterministic_per_seed() {
        let a = Arrival::Poisson { rate_hz: 50.0 }.schedule(1e6, &mut Rng::new(3));
        let b = Arrival::Poisson { rate_hz: 50.0 }.schedule(1e6, &mut Rng::new(3));
        assert_eq!(a, b);
    }

    #[test]
    fn closed_loop_seeds_one_request_per_client() {
        let s = Arrival::ClosedLoop { clients: 1 }.schedule(1e6, &mut Rng::new(4));
        assert_eq!(s, vec![0.0]);
        let s3 = Arrival::ClosedLoop { clients: 3 }.schedule(1e6, &mut Rng::new(4));
        assert_eq!(s3, vec![0.0, 0.0, 0.0]);
        assert!(Arrival::ClosedLoop { clients: 2 }.is_closed_loop());
    }

    // --- statistical sanity (ISSUE 2 satellite): empirical mean
    // inter-arrival within 5% of nominal over >= 10k draws at fixed seeds.

    #[test]
    fn uniform_mean_interarrival_within_5pct() {
        let s = Arrival::Uniform { rate_hz: 1000.0 }
            .schedule(10e6, &mut Rng::new(0x51));
        assert!(s.len() >= 10_000, "{}", s.len());
        let mean = mean_interarrival(&s);
        assert!((mean - 1000.0).abs() < 50.0, "mean {mean}");
    }

    #[test]
    fn poisson_mean_interarrival_within_5pct() {
        // Pool several fixed seeds so the bound sits many standard
        // deviations out (sd of the pooled mean is ~0.5%).
        let mut total = 0usize;
        let mut span = 0.0;
        for seed in [0x90, 0x91, 0x92, 0x93] {
            let s = Arrival::Poisson { rate_hz: 1000.0 }
                .schedule(10e6, &mut Rng::new(seed));
            assert!(s.len() >= 9_000, "seed {seed}: {}", s.len());
            assert_sorted(&s);
            total += s.len() - 1;
            span += s.last().unwrap() - s.first().unwrap();
        }
        let mean = span / total as f64;
        assert!((mean - 1000.0).abs() < 50.0, "mean {mean}");
    }

    #[test]
    fn mmpp_mean_rate_within_5pct_and_bursty() {
        // Symmetric on/off sojourns at 2 kHz on, silent off -> nominal
        // 1 kHz; ~20k draws per seed, pooled over 4 seeds.
        let a = Arrival::Mmpp {
            on_hz: 2000.0,
            off_hz: 0.0,
            mean_on_us: 5_000.0,
            mean_off_us: 5_000.0,
        };
        assert!((a.nominal_rate_hz().unwrap() - 1000.0).abs() < 1e-9);
        let mut total = 0usize;
        let mut span = 0.0;
        let mut max_gap: f64 = 0.0;
        for seed in [0xA0, 0xA1, 0xA2, 0xA3] {
            let s = a.schedule(20e6, &mut Rng::new(seed));
            assert!(s.len() >= 10_000, "seed {seed}: {}", s.len());
            assert_sorted(&s);
            for w in s.windows(2) {
                max_gap = max_gap.max(w[1] - w[0]);
            }
            total += s.len() - 1;
            span += s.last().unwrap() - s.first().unwrap();
        }
        let mean = span / total as f64;
        assert!((mean - 1000.0).abs() < 50.0, "mean {mean}");
        // Burstiness: off sojourns leave gaps far above the mean gap.
        assert!(max_gap > 10.0 * mean, "max gap {max_gap} vs mean {mean}");
    }

    #[test]
    fn ramp_count_matches_rate_integral_and_mean_within_5pct() {
        // 500 -> 1500 Hz over 10 s: integral = 10_000 arrivals exactly.
        let s = Arrival::Ramp { start_hz: 500.0, end_hz: 1500.0 }
            .schedule(10e6, &mut Rng::new(0xC0));
        assert!((9_999..=10_001).contains(&s.len()), "{}", s.len());
        assert_sorted(&s);
        let mean = mean_interarrival(&s);
        assert!((mean - 1000.0).abs() < 50.0, "mean {mean}");
        // Accelerating ramp: the first gap is longer than the last.
        assert!(s[1] - s[0] > s[s.len() - 1] - s[s.len() - 2]);
    }

    #[test]
    fn ramp_decreasing_runs_dry() {
        // 20 -> 0 Hz over 1 s: integral = 10 arrivals, none past the point
        // where the intensity is exhausted.
        let s = Arrival::Ramp { start_hz: 20.0, end_hz: 0.0 }
            .schedule(1e6, &mut Rng::new(0xC1));
        assert_eq!(s.len(), 10, "{s:?}");
        assert_sorted(&s);
        assert!((s[0] - 0.0).abs() < 1e-9);
    }

    #[test]
    fn ramp_flat_degenerates_to_uniform() {
        let mut rng = Rng::new(0xC2);
        let r = Arrival::Ramp { start_hz: 10.0, end_hz: 10.0 }
            .schedule(1e6, &mut rng);
        let u = Arrival::Uniform { rate_hz: 10.0 }.schedule(1e6, &mut rng);
        assert_eq!(r.len(), u.len());
        for (a, b) in r.iter().zip(&u) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn mmpp_deterministic_per_seed() {
        let a = Arrival::Mmpp {
            on_hz: 500.0,
            off_hz: 20.0,
            mean_on_us: 10_000.0,
            mean_off_us: 30_000.0,
        };
        assert_eq!(
            a.schedule(1e6, &mut Rng::new(9)),
            a.schedule(1e6, &mut Rng::new(9))
        );
    }

    #[test]
    fn replay_returns_recorded_times_truncated_to_window() {
        let a = Arrival::replay(vec![300.0, 100.0, 200.0, 900.0]);
        let s = a.schedule(500.0, &mut Rng::new(1));
        assert_eq!(s, vec![100.0, 200.0, 300.0]);
        assert!(!a.is_closed_loop());
        // Replay ignores the RNG entirely: same schedule for any seed.
        assert_eq!(s, a.schedule(500.0, &mut Rng::new(77)));
    }

    #[test]
    fn nominal_rates() {
        assert_eq!(
            Arrival::Uniform { rate_hz: 5.0 }.nominal_rate_hz(),
            Some(5.0)
        );
        assert_eq!(
            Arrival::Ramp { start_hz: 10.0, end_hz: 30.0 }.nominal_rate_hz(),
            Some(20.0)
        );
        assert_eq!(Arrival::ClosedLoop { clients: 2 }.nominal_rate_hz(), None);
        assert_eq!(Arrival::replay(vec![]).nominal_rate_hz(), None);
        assert_eq!(
            Arrival::Modulated {
                rate_hz: 7.0,
                curve: Arc::new(RateCurve::flat()),
            }
            .nominal_rate_hz(),
            Some(7.0)
        );
    }

    // --- ISSUE 7: the lazy stream form must match the materialized
    // schedule draw for draw (same arrivals AND same RNG end state).

    /// Collect a stream to exhaustion and check it equals `schedule`
    /// over an identically-seeded RNG, then prove both RNGs are in the
    /// same state by comparing one more draw.
    fn assert_stream_matches_schedule(a: &Arrival, duration_us: f64, seed: u64) {
        let mut rng_sched = Rng::new(seed);
        let expect = a.schedule(duration_us, &mut rng_sched);
        let mut rng_stream = Rng::new(seed);
        let mut s = a.stream(duration_us);
        let mut got = Vec::new();
        while let Some(t) = s.next(&mut rng_stream) {
            got.push(t);
        }
        assert_eq!(got, expect, "{a:?} seed {seed}");
        assert_eq!(
            rng_stream.next_u64(),
            rng_sched.next_u64(),
            "RNG state diverged: {a:?} seed {seed}"
        );
        // Exhausted streams stay exhausted without consuming draws.
        let probe = rng_stream.next_u64();
        assert_eq!(s.next(&mut rng_stream), None);
        let mut replayed = Rng::new(seed);
        a.schedule(duration_us, &mut replayed);
        replayed.next_u64();
        assert_eq!(replayed.next_u64(), probe);
    }

    #[test]
    fn stream_matches_schedule_uniform() {
        let a = Arrival::Uniform { rate_hz: 250.0 };
        for seed in [1, 0x5CA1E] {
            assert_stream_matches_schedule(&a, 1e6, seed);
        }
    }

    #[test]
    fn stream_matches_schedule_poisson() {
        let a = Arrival::Poisson { rate_hz: 800.0 };
        for seed in [2, 42, 0xBEEF] {
            assert_stream_matches_schedule(&a, 2e6, seed);
        }
        // Zero-length window: schedule still burns the first draw.
        assert_stream_matches_schedule(&a, 0.0, 7);
    }

    #[test]
    fn stream_matches_schedule_closed_loop() {
        assert_stream_matches_schedule(
            &Arrival::ClosedLoop { clients: 4 },
            1e6,
            3,
        );
    }

    #[test]
    fn stream_matches_schedule_mmpp() {
        let a = Arrival::Mmpp {
            on_hz: 2000.0,
            off_hz: 0.0,
            mean_on_us: 5_000.0,
            mean_off_us: 5_000.0,
        };
        for seed in [0xA0, 0xA1, 9] {
            assert_stream_matches_schedule(&a, 5e6, seed);
        }
        let b = Arrival::Mmpp {
            on_hz: 500.0,
            off_hz: 20.0,
            mean_on_us: 10_000.0,
            mean_off_us: 30_000.0,
        };
        assert_stream_matches_schedule(&b, 5e6, 0xC0FFEE);
    }

    #[test]
    fn stream_matches_schedule_ramp() {
        for a in [
            Arrival::Ramp { start_hz: 500.0, end_hz: 1500.0 },
            Arrival::Ramp { start_hz: 20.0, end_hz: 0.0 },
            Arrival::Ramp { start_hz: 10.0, end_hz: 10.0 },
        ] {
            for seed in [0xC0, 5] {
                assert_stream_matches_schedule(&a, 1e6, seed);
            }
        }
    }

    #[test]
    fn stream_matches_schedule_replay() {
        let a = Arrival::replay(vec![300.0, 100.0, 200.0, 900.0]);
        assert_stream_matches_schedule(&a, 500.0, 1);
        assert_stream_matches_schedule(&a, 1e6, 1);
    }

    #[test]
    fn stream_matches_schedule_modulated() {
        let curve = Arc::new(RateCurve {
            period_us: 200_000.0,
            depth: 0.6,
            flash_at_us: 300_000.0,
            flash_dur_us: 50_000.0,
            flash_boost: 4.0,
        });
        let a = Arrival::Modulated { rate_hz: 500.0, curve };
        for seed in [0x5CA1E, 42, 1234] {
            assert_stream_matches_schedule(&a, 1e6, seed);
        }
    }

    // --- ISSUE 7: modulated-process behavior.

    #[test]
    fn modulated_deterministic_sorted_and_in_window() {
        let curve = Arc::new(RateCurve {
            period_us: 100_000.0,
            depth: 0.5,
            flash_at_us: 400_000.0,
            flash_dur_us: 100_000.0,
            flash_boost: 3.0,
        });
        let a = Arrival::Modulated { rate_hz: 1000.0, curve };
        let s = a.schedule(1e6, &mut Rng::new(11));
        assert_eq!(s, a.schedule(1e6, &mut Rng::new(11)));
        assert_sorted(&s);
        assert!(!s.is_empty());
        assert!(*s.last().unwrap() < 1e6);
        assert!(*s.first().unwrap() >= 0.0);
    }

    #[test]
    fn modulated_mean_rate_within_5pct() {
        // Whole diurnal periods, no flash: the mean factor is 1, so the
        // empirical count should sit near base_rate * duration. Pool
        // seeds to push the bound many standard deviations out.
        let curve = Arc::new(RateCurve {
            period_us: 1_000_000.0,
            depth: 0.8,
            flash_at_us: 0.0,
            flash_dur_us: 0.0,
            flash_boost: 1.0,
        });
        let a = Arrival::Modulated { rate_hz: 1000.0, curve };
        let mut total = 0usize;
        for seed in [0xD0, 0xD1, 0xD2, 0xD3] {
            total += a.schedule(10e6, &mut Rng::new(seed)).len();
        }
        let expect = 4.0 * 10_000.0;
        let err = (total as f64 - expect).abs() / expect;
        assert!(err < 0.05, "total {total} vs {expect}");
    }

    #[test]
    fn modulated_flash_crowd_concentrates_arrivals() {
        // A 5x flash over 10% of the window should hold far more than
        // 10% of the arrivals — the flash-crowd signature the scale
        // scenarios rely on.
        let curve = Arc::new(RateCurve {
            period_us: 1_000_000.0,
            depth: 0.0,
            flash_at_us: 450_000.0,
            flash_dur_us: 100_000.0,
            flash_boost: 5.0,
        });
        let a = Arrival::Modulated { rate_hz: 500.0, curve: curve.clone() };
        let s = a.schedule(1e6, &mut Rng::new(0xF1A5));
        let in_flash =
            s.iter().filter(|t| curve.in_flash(**t)).count() as f64;
        let frac = in_flash / s.len() as f64;
        assert!(frac > 0.25, "flash fraction {frac}");
        // Envelope accounting: mean_factor reflects the same overlap.
        assert!((curve.mean_factor(1e6) - 1.4).abs() < 1e-9);
    }
}
