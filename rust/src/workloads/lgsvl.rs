//! LGSVL autonomous-driving case study workload (paper §8.5).
//!
//! The paper replays a trace collected from the LG SVL simulator's 3D
//! lidar + 2D camera perception modules: obstacle detection (ResNet
//! backbone, camera) as the critical task at 10 Hz and pose estimation
//! (SqueezeNet backbone, lidar) as the normal task at 12.5 Hz, both in
//! uniform distribution, on the RTX 2060. The trace itself is not
//! published; per the substitution rule we regenerate it from the
//! published arrival statistics, with optional jitter emulating sensor
//! timestamp noise.

use std::sync::Arc;

use crate::gpu::kernel::Criticality;
use crate::workloads::arrival::Arrival;
use crate::workloads::mdtb::{Source, Workload};
use crate::workloads::models;
use crate::workloads::rng::Rng;

/// Build the LGSVL-style workload (paper Fig. 12 (c) settings).
pub fn workload(duration_us: f64) -> Workload {
    Workload {
        name: "LGSVL".into(),
        sources: vec![
            Source {
                model: Arc::new(models::resnet()),
                arrival: Arrival::Uniform { rate_hz: 10.0 },
                criticality: Criticality::Critical,
                deadline_us: None,
            },
            Source {
                model: Arc::new(models::squeezenet()),
                arrival: Arrival::Uniform { rate_hz: 12.5 },
                criticality: Criticality::Normal,
                deadline_us: None,
            },
        ],
        duration_us,
        seed: 0x1651,
    }
}

/// A replayable trace row: (arrival_us, source index).
pub type TraceRow = (f64, usize);

/// Generate the merged sensor trace with bounded timestamp jitter
/// (uniform +-`jitter_us`), sorted by time — what a rosbag replay of the
/// LGSVL perception topics looks like.
pub fn trace(duration_us: f64, jitter_us: f64, seed: u64) -> Vec<TraceRow> {
    let mut rng = Rng::new(seed);
    let mut rows: Vec<TraceRow> = Vec::new();
    let w = workload(duration_us);
    for (i, src) in w.sources.iter().enumerate() {
        for t in src.arrival.schedule(duration_us, &mut rng) {
            let j = (rng.next_f64() * 2.0 - 1.0) * jitter_us;
            rows.push(((t + j).max(0.0), i));
        }
    }
    rows.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_rates() {
        let w = workload(1e6);
        assert_eq!(w.sources[0].model.name, "resnet");
        assert_eq!(w.sources[0].criticality, Criticality::Critical);
        assert!(matches!(w.sources[0].arrival, Arrival::Uniform { rate_hz }
            if (rate_hz - 10.0).abs() < 1e-9));
        assert!(matches!(w.sources[1].arrival, Arrival::Uniform { rate_hz }
            if (rate_hz - 12.5).abs() < 1e-9));
    }

    #[test]
    fn trace_counts_and_order() {
        // 2 seconds: 20 critical + 25 normal arrivals.
        let rows = trace(2e6, 0.0, 1);
        assert_eq!(rows.len(), 45);
        assert_eq!(rows.iter().filter(|r| r.1 == 0).count(), 20);
        for w in rows.windows(2) {
            assert!(w[1].0 >= w[0].0);
        }
    }

    #[test]
    fn jitter_stays_positive_and_sorted() {
        let rows = trace(1e6, 500.0, 7);
        for w in rows.windows(2) {
            assert!(w[1].0 >= w[0].0);
        }
        assert!(rows.iter().all(|r| r.0 >= 0.0));
    }

    #[test]
    fn trace_deterministic() {
        assert_eq!(trace(1e6, 100.0, 3), trace(1e6, 100.0, 3));
    }
}
