//! Autoregressive (prefill/decode) generation workloads (ISSUE 10
//! tentpole).
//!
//! Every earlier workload is a CNN-style fixed kernel chain; a
//! generation request is long-lived and stateful: one *prefill* pass
//! over the prompt builds the KV cache and emits the first token, then
//! one *decode* step per output token re-launches a small kernel graph
//! whose attention kernel grows with the KV-cache length — the regime
//! of the mirage llama3 decode loop (SNIPPETS.md: rms-linear QKV →
//! attention over the cache → output projection → gate/up → down, with
//! a per-step relaunch). Deadlines change shape too: a generation
//! tenant carries a time-to-first-token (TTFT) deadline plus a
//! per-token budget instead of one end-to-end deadline ("EdgeServing",
//! PAPERS.md).
//!
//! This module is purely *descriptive*: [`GenModelDesc`] builds
//! bucketed prefill/decode kernel graphs as ordinary
//! [`ModelDesc`]s, [`GenScenarioSpec`] names a mixed-criticality tenant
//! set over a device KV budget, and [`gen_family`] enumerates the named
//! scenarios `miriam gen-sim` runs. The serving state machine that
//! drives these graphs (KV ledger, eviction, continuous batching) lives
//! in [`crate::server::gen`].

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::coordinator::sweep::derive_seed;
use crate::gpu::kernel::{Criticality, KernelDesc};
use crate::workloads::arrival::Arrival;
use crate::workloads::mdtb::{Source, Workload};
use crate::workloads::models::{ModelDesc, ModelRef};
use crate::workloads::rng::Rng;

/// Threads per block for generation kernels (GEMV-shaped).
const TPB: u32 = 256;
/// Output elements per thread (work coarsening), as in `models.rs`.
const WPT: u32 = 8;
/// Compute efficiency of naive matmul kernels relative to peak — the
/// same calibration constant family as `models::CONV_EFF` (those are
/// private to their module by design; each descriptor family owns its
/// own calibration).
const MM_EFF: f64 = 0.08;
/// Achieved DRAM-bandwidth efficiency of strided accesses.
const MEM_EFF: f64 = 0.55;
/// fp16 weights/activations/KV entries.
const BYTES_PER_EL: f64 = 2.0;
/// Decode GEMVs split their rows across extra blocks (as `models::fc`
/// does) so a single-token step still spreads over several SMs.
const GEMV_SPLIT: u64 = 16;

fn grid_for(out_elems: u64, tpb: u32) -> u32 {
    (out_elems.div_ceil((tpb * WPT) as u64)).max(1) as u32
}

/// A transformer-ish generation model: enough shape to derive bucketed
/// prefill and decode kernel graphs plus per-token KV-cache cost.
///
/// Graphs are *bucketed*: prompt lengths round up to
/// [`GenModelDesc::prompt_bucket`] and KV lengths to
/// [`GenModelDesc::kv_bucket`], so the set of distinct kernel names a
/// run interns is small and the per-step resubmit path stays on the
/// zero-alloc interned fast path (ISSUE 3).
#[derive(Debug, Clone)]
pub struct GenModelDesc {
    /// Model name (e.g. "llama-edge").
    pub name: String,
    /// Hidden dimension (`n_heads * head_dim`).
    pub hidden: u32,
    /// MLP intermediate dimension (gate/up width).
    pub intermediate: u32,
    /// Query head count.
    pub n_heads: u32,
    /// KV head count (grouped-query attention; KV bytes scale with
    /// this, not `n_heads`).
    pub n_kv_heads: u32,
    /// Per-head dimension.
    pub head_dim: u32,
    /// Prompt-length bucket granularity (tokens) for prefill graphs.
    pub prompt_bucket: u32,
    /// KV-length bucket granularity (tokens) for decode graphs.
    pub kv_bucket: u32,
    /// Maximum context (prompt + output tokens) a request may use.
    pub max_context: u32,
}

impl GenModelDesc {
    /// KV width per token (elements): K and V rows across the KV heads.
    pub fn kv_dim(&self) -> u64 {
        (self.n_kv_heads * self.head_dim) as u64
    }

    /// KV-cache bytes one token occupies (K + V, fp16).
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.kv_dim() as f64 * BYTES_PER_EL
    }

    /// KV-cache bytes a request holding `tokens` cache entries occupies.
    pub fn kv_bytes(&self, tokens: u32) -> f64 {
        tokens as f64 * self.kv_bytes_per_token()
    }

    /// Round a prompt length up to its graph bucket, clamped to
    /// [`GenModelDesc::max_context`].
    pub fn prompt_bucketed(&self, len: u32) -> u32 {
        let b = self.prompt_bucket.max(1);
        (len.max(1).div_ceil(b) * b).min(self.max_context)
    }

    /// Round a KV length up to its graph bucket, clamped to
    /// [`GenModelDesc::max_context`].
    pub fn kv_bucketed(&self, len: u32) -> u32 {
        let b = self.kv_bucket.max(1);
        (len.max(1).div_ceil(b) * b).min(self.max_context)
    }

    fn gemv(&self, name: String, seq: u64, din: u64, dout: u64)
            -> KernelDesc {
        let out = seq * dout;
        // Single-token GEMVs split rows across blocks; prefill has
        // sequence-level parallelism already.
        let grid_elems = if seq == 1 { out * GEMV_SPLIT } else { out };
        KernelDesc {
            name,
            grid: grid_for(grid_elems, TPB),
            block_threads: TPB,
            smem_per_block: 2 * 1024,
            regs_per_thread: 32,
            flops: 2.0 * (seq * din * dout) as f64 / MM_EFF,
            bytes: BYTES_PER_EL * (din * dout + seq * (din + dout)) as f64
                / MEM_EFF,
        }
    }

    /// The attention kernel over a `kv_len`-entry cache for `seq` query
    /// tokens. Both its effective FLOPs and its DRAM traffic grow
    /// linearly with `kv_len` (the cache read), and its grid grows with
    /// `kv_len` too — the cost/footprint growth the decode loop exists
    /// to exercise.
    fn attention(&self, name: String, seq: u64, kv_len: u64) -> KernelDesc {
        let h = self.hidden as u64;
        KernelDesc {
            name,
            grid: grid_for(seq * kv_len * self.n_heads as u64 * 8, TPB),
            block_threads: TPB,
            smem_per_block: 4 * 1024,
            regs_per_thread: 40,
            // QK^T plus PV over every cache entry.
            flops: 4.0 * (seq * kv_len * h) as f64 / MM_EFF,
            bytes: (self.kv_bytes(kv_len as u32)
                + BYTES_PER_EL * (seq * h) as f64)
                / MEM_EFF,
        }
    }

    fn graph(&self, tag: &str, seq: u64, kv_len: u64) -> ModelDesc {
        let m = &self.name;
        let h = self.hidden as u64;
        let inter = self.intermediate as u64;
        let kv = self.kv_dim();
        let kernels = vec![
            // rms_linear: fused RMSNorm + QKV projection.
            self.gemv(format!("{m}/{tag}/qkv"), seq, h, h + 2 * kv),
            self.attention(format!("{m}/{tag}/attn"), seq, kv_len),
            // Output projection.
            self.gemv(format!("{m}/{tag}/wo"), seq, h, h),
            // Gate + up projections (fused, SwiGLU-style).
            self.gemv(format!("{m}/{tag}/w13"), seq, h, 2 * inter),
            // Down projection.
            self.gemv(format!("{m}/{tag}/w2"), seq, inter, h),
        ];
        ModelDesc { name: format!("{m}:{tag}"), kernels }
    }

    /// The prefill kernel graph for a prompt of `prompt_len` tokens
    /// (bucketed): processes the whole prompt, builds the KV cache, and
    /// emits the first output token on completion.
    pub fn prefill_graph(&self, prompt_len: u32) -> ModelDesc {
        let p = self.prompt_bucketed(prompt_len) as u64;
        self.graph(&format!("p{p}"), p, p)
    }

    /// One decode step against a `kv_len`-entry cache (bucketed):
    /// a single query token, five small launches, attention cost
    /// growing with the cache.
    pub fn decode_graph(&self, kv_len: u32) -> ModelDesc {
        let k = self.kv_bucketed(kv_len) as u64;
        self.graph(&format!("d{k}"), 1, k)
    }

    /// A continuous-batching decode step: `batch` requests sharing one
    /// launch per kernel. Grids, FLOPs, and bytes scale by `batch`
    /// (every member pays the *bucketed* KV read — the padding cost the
    /// Miriam comparison measures); launch overhead is paid once, which
    /// is the throughput win. `batch == 1` is exactly
    /// [`GenModelDesc::decode_graph`] (same kernel names, so no extra
    /// interning).
    pub fn decode_graph_batched(&self, kv_len: u32, batch: u32) -> ModelDesc {
        if batch <= 1 {
            return self.decode_graph(kv_len);
        }
        let mut g = self.decode_graph(kv_len);
        let k = self.kv_bucketed(kv_len);
        for kd in &mut g.kernels {
            // "{m}/d{k}/qkv" -> "{m}/d{k}/b{batch}/qkv"
            let leaf = kd.name.rsplit('/').next().unwrap_or("k").to_string();
            kd.name = format!("{}/d{k}/b{batch}/{leaf}", self.name);
            kd.grid = kd.grid.saturating_mul(batch).max(1);
            kd.flops *= batch as f64;
            kd.bytes *= batch as f64;
        }
        g.name = format!("{}:d{k}:b{batch}", self.name);
        g
    }

    /// The *expected-work* graph of one whole request from this model:
    /// prefill over the prompt plus `round(mean_output)` decode steps at
    /// the request's mid-life KV length. Used only to build admission
    /// envelopes for best-effort tenants, so the deadline-feasible
    /// burst guard sees a request's real service demand, not just its
    /// prefill (the prefill/decode admission split of ISSUE 10).
    pub fn expected_request_graph(&self, prompt_len: u32, mean_output: f64)
                                  -> ModelDesc {
        let steps = (mean_output.round() as u32).max(1);
        let mut g = self.prefill_graph(prompt_len);
        let mid = self
            .prompt_bucketed(prompt_len)
            .saturating_add(steps / 2)
            .min(self.max_context);
        let step = self.decode_graph(mid);
        for _ in 0..steps {
            g.kernels.extend(step.kernels.iter().cloned());
        }
        g.name = format!("{}:req-p{}", self.name, prompt_len);
        g
    }
}

/// Generation model registry.
pub fn gen_model_by_name(name: &str) -> Option<GenModelDesc> {
    match name {
        // Scaled-down llama3-shaped edge model (SNIPPETS.md).
        "llama-edge" => Some(GenModelDesc {
            name: "llama-edge".into(),
            hidden: 512,
            intermediate: 1408,
            n_heads: 8,
            n_kv_heads: 4,
            head_dim: 64,
            prompt_bucket: 32,
            kv_bucket: 32,
            max_context: 512,
        }),
        // Chat-assistant nano variant for critical short-form tenants.
        "llama-nano" => Some(GenModelDesc {
            name: "llama-nano".into(),
            hidden: 256,
            intermediate: 704,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 64,
            prompt_bucket: 16,
            kv_bucket: 16,
            max_context: 256,
        }),
        _ => None,
    }
}

/// All generation model names.
pub const GEN_MODELS: [&str; 2] = ["llama-edge", "llama-nano"];

/// One generation tenant: a stream of requests sharing a model, a
/// prompt shape, an output-length distribution, and token-level SLOs.
#[derive(Debug, Clone)]
pub struct GenSourceSpec {
    /// Generation model name, resolved through [`gen_model_by_name`].
    pub model: String,
    /// Task class of every request from this source.
    pub criticality: Criticality,
    /// How requests arrive (open-loop processes only — a generation
    /// request's lifetime is its decode chain, not a closed loop).
    pub arrival: Arrival,
    /// Prompt length (tokens) of every request from this source.
    pub prompt_len: u32,
    /// Mean of the bounded-geometric output-length draw (tokens, >= 1).
    pub mean_output: f64,
    /// Hard cap on drawn output lengths (tokens, >= 1).
    pub max_output: u32,
    /// Time-to-first-token deadline (us), if any.
    pub ttft_deadline_us: Option<f64>,
    /// Per-token (inter-token gap) budget (us), if any.
    pub per_token_us: Option<f64>,
}

impl GenSourceSpec {
    /// Draw this source's output length for one request: a bounded
    /// geometric on `1..=max_output` with the configured mean, fully
    /// determined by `seed` (derive it per request with
    /// [`request_seed`], never from the arrival RNG — arrival streams
    /// must match the fixed-chain equivalent bitwise).
    pub fn draw_output_len(&self, seed: u64) -> u32 {
        let mut rng = Rng::new(seed.max(1));
        let mean = self.mean_output.max(1.0);
        let q = 1.0 - 1.0 / mean;
        let mut len = 1u32;
        while len < self.max_output && rng.next_f64() < q {
            len += 1;
        }
        len
    }
}

/// The seed of the output-length draw for request number `ordinal`
/// (0-based, per source) of source `src` in a scenario seeded `seed`.
/// Splitmix-derived per (source, ordinal) so a source's draws are
/// identical whether or not other tenants exist (the solo-criticals
/// comparison and the threads determinism gate rely on this).
pub fn request_seed(seed: u64, src: usize, ordinal: u64) -> u64 {
    let s = derive_seed(seed ^ 0x9E37_79B9_7F4A_7C15, src as u32 + 1);
    derive_seed(s, (ordinal as u32).wrapping_add(1).max(1))
}

/// A complete generation scenario: N tenants over a simulated window,
/// sharing one device KV budget.
#[derive(Debug, Clone)]
pub struct GenScenarioSpec {
    /// Scenario name (unique within the gen family).
    pub name: String,
    /// The tenants, in source order. Critical tenants come first, so
    /// the solo-criticals variant preserves their arrival RNG draws.
    pub sources: Vec<GenSourceSpec>,
    /// Arrival-generation window (us). Decode chains in flight at the
    /// end of the window drain to completion.
    pub duration_us: f64,
    /// RNG seed for arrivals and per-request output-length draws.
    pub seed: u64,
    /// Device KV-cache budget (bytes) shared by all resident requests.
    pub kv_budget_bytes: f64,
}

impl GenScenarioSpec {
    /// Number of request sources (tenants).
    pub fn tenants(&self) -> usize {
        self.sources.len()
    }

    /// Stable per-tenant label, same shape as
    /// [`crate::workloads::scenario::ScenarioSpec::tenant_label`].
    pub fn tenant_label(&self, i: usize) -> String {
        let s = &self.sources[i];
        let class = match s.criticality {
            Criticality::Critical => "critical",
            Criticality::Normal => "normal",
        };
        format!("t{i}-{}-{class}", s.model)
    }

    /// Number of critical tenants.
    pub fn criticals(&self) -> usize {
        self.sources
            .iter()
            .filter(|s| s.criticality == Criticality::Critical)
            .count()
    }

    /// Validate the scenario: models resolve, shapes fit the context
    /// window, every per-request KV footprint fits the budget alone
    /// (otherwise a request could park forever), arrivals are
    /// open-loop, and criticals precede normals.
    pub fn validate(&self) -> Result<(), String> {
        if self.sources.is_empty() {
            return Err(format!("{}: no sources", self.name));
        }
        if !(self.duration_us > 0.0) {
            return Err(format!("{}: non-positive duration", self.name));
        }
        if !self.kv_budget_bytes.is_finite() || self.kv_budget_bytes <= 0.0 {
            return Err(format!("{}: invalid kv budget", self.name));
        }
        let mut seen_normal = false;
        for (i, s) in self.sources.iter().enumerate() {
            let m = gen_model_by_name(&s.model).ok_or_else(|| {
                format!("{}: unknown gen model {}", self.name, s.model)
            })?;
            if s.prompt_len == 0 || s.max_output == 0 {
                return Err(format!("{}: t{i} zero prompt/output", self.name));
            }
            if !(s.mean_output >= 1.0) {
                return Err(format!("{}: t{i} mean_output < 1", self.name));
            }
            if s.prompt_len + s.max_output > m.max_context {
                return Err(format!(
                    "{}: t{i} prompt {} + max_output {} exceeds {} context {}",
                    self.name, s.prompt_len, s.max_output, s.model,
                    m.max_context
                ));
            }
            let footprint = m.kv_bytes(s.prompt_len + s.max_output);
            if footprint > self.kv_budget_bytes {
                return Err(format!(
                    "{}: t{i} max KV footprint {footprint} exceeds budget {}",
                    self.name, self.kv_budget_bytes
                ));
            }
            if s.arrival.is_closed_loop() {
                return Err(format!(
                    "{}: t{i} closed-loop arrivals unsupported for \
                     generation tenants",
                    self.name
                ));
            }
            match s.criticality {
                Criticality::Normal => seen_normal = true,
                Criticality::Critical if seen_normal => {
                    return Err(format!(
                        "{}: critical t{i} after a normal tenant (criticals \
                         must come first for solo-run arrival parity)",
                        self.name
                    ));
                }
                Criticality::Critical => {}
            }
        }
        Ok(())
    }

    /// The scenario's *prefill* workload: one [`Source`] per tenant
    /// whose model is the tenant's prefill graph and whose deadline is
    /// its TTFT deadline. This is what the serving loop draws arrivals
    /// from and submits as each request's first phase — and, for
    /// 1-token scenarios, exactly the fixed-chain equivalent workload
    /// of the differential test (decode machinery inert). Tenants
    /// sharing (model, prompt bucket) share one [`ModelRef`], so the
    /// device core interns each distinct graph once.
    pub fn base_workload(&self) -> Workload {
        let mut cache: BTreeMap<(String, u32), ModelRef> = BTreeMap::new();
        let sources = self
            .sources
            .iter()
            .map(|s| {
                let m = gen_model_by_name(&s.model)
                    .unwrap_or_else(|| panic!("unknown gen model {}", s.model));
                let bucket = m.prompt_bucketed(s.prompt_len);
                let arc = cache
                    .entry((s.model.clone(), bucket))
                    .or_insert_with(|| Arc::new(m.prefill_graph(s.prompt_len)))
                    .clone();
                Source {
                    model: arc,
                    arrival: s.arrival.clone(),
                    criticality: s.criticality,
                    deadline_us: s.ttft_deadline_us,
                }
            })
            .collect();
        Workload {
            name: self.name.clone(),
            sources,
            duration_us: self.duration_us,
            seed: self.seed,
        }
    }

    /// The workload the admission controller sizes its envelopes from
    /// (the prefill/decode split): critical tenants keep their prefill
    /// graph + TTFT deadline, so deadline-feasible admission binds on
    /// TTFT; best-effort tenants get their whole expected-request graph
    /// ([`GenModelDesc::expected_request_graph`]), so the burst guard
    /// sees real decode backlog, not just prefill.
    pub fn admission_workload(&self) -> Workload {
        let sources = self
            .sources
            .iter()
            .map(|s| {
                let m = gen_model_by_name(&s.model)
                    .unwrap_or_else(|| panic!("unknown gen model {}", s.model));
                let (model, deadline) = match s.criticality {
                    Criticality::Critical => (
                        Arc::new(m.prefill_graph(s.prompt_len)),
                        s.ttft_deadline_us,
                    ),
                    Criticality::Normal => (
                        Arc::new(m.expected_request_graph(
                            s.prompt_len,
                            s.mean_output,
                        )),
                        None,
                    ),
                };
                Source {
                    model,
                    arrival: s.arrival.clone(),
                    criticality: s.criticality,
                    deadline_us: deadline,
                }
            })
            .collect();
        Workload {
            name: self.name.clone(),
            sources,
            duration_us: self.duration_us,
            seed: self.seed,
        }
    }

    /// The solo-criticals variant: identical critical tenants, normal
    /// tenants replaced by empty replay streams (so source indices,
    /// labels, and — because criticals precede normals — the criticals'
    /// arrival RNG draws are all preserved). The TTFT acceptance gate
    /// compares mixed-run critical TTFT against this run.
    pub fn solo_criticals(&self) -> GenScenarioSpec {
        GenScenarioSpec {
            name: format!("{}-solo", self.name),
            sources: self
                .sources
                .iter()
                .map(|s| {
                    if s.criticality == Criticality::Critical {
                        s.clone()
                    } else {
                        GenSourceSpec {
                            arrival: Arrival::replay(Vec::new()),
                            ..s.clone()
                        }
                    }
                })
                .collect(),
            duration_us: self.duration_us,
            seed: self.seed,
            kv_budget_bytes: self.kv_budget_bytes,
        }
    }
}

fn gcrit(model: &str, arrival: Arrival, prompt: u32, mean: f64, max: u32,
         ttft_us: f64, per_token_us: f64) -> GenSourceSpec {
    GenSourceSpec {
        model: model.into(),
        criticality: Criticality::Critical,
        arrival,
        prompt_len: prompt,
        mean_output: mean,
        max_output: max,
        ttft_deadline_us: Some(ttft_us),
        per_token_us: Some(per_token_us),
    }
}

fn gnorm(model: &str, arrival: Arrival, prompt: u32, mean: f64, max: u32)
         -> GenSourceSpec {
    GenSourceSpec {
        model: model.into(),
        criticality: Criticality::Normal,
        arrival,
        prompt_len: prompt,
        mean_output: mean,
        max_output: max,
        ttft_deadline_us: None,
        per_token_us: None,
    }
}

/// The named generation scenario family: critical short-prompt /
/// short-output chat tenants against normal long-generation tenants,
/// under progressively tighter KV budgets.
pub fn gen_family(duration_us: f64) -> Vec<GenScenarioSpec> {
    vec![
        // Roomy budget: the no-pressure anchor (no evictions expected);
        // two of its cells are golden-trace pins.
        GenScenarioSpec {
            name: "gen-duo".into(),
            sources: vec![
                gcrit(
                    "llama-nano",
                    Arrival::Uniform { rate_hz: 120.0 },
                    24, 4.0, 8, 8_000.0, 4_000.0,
                ),
                gnorm(
                    "llama-edge",
                    Arrival::Poisson { rate_hz: 70.0 },
                    96, 12.0, 24,
                ),
            ],
            duration_us,
            seed: 0x6E1,
            kv_budget_bytes: 524_288.0,
        },
        // Tight budget: two long-generation tenants collide, parking
        // normals and forcing evict-and-recompute when criticals land
        // while the cache is full.
        GenScenarioSpec {
            name: "gen-pressure".into(),
            sources: vec![
                gcrit(
                    "llama-nano",
                    Arrival::Uniform { rate_hz: 100.0 },
                    16, 4.0, 8, 8_000.0, 4_000.0,
                ),
                gnorm(
                    "llama-edge",
                    Arrival::Mmpp {
                        on_hz: 250.0,
                        off_hz: 10.0,
                        mean_on_us: 4_000.0,
                        mean_off_us: 8_000.0,
                    },
                    128, 24.0, 48,
                ),
                gnorm(
                    "llama-edge",
                    Arrival::Poisson { rate_hz: 50.0 },
                    128, 16.0, 32,
                ),
            ],
            duration_us,
            seed: 0x6E2,
            kv_budget_bytes: 368_640.0,
        },
        // Widest mix: two critical classes, two bursty long tenants.
        GenScenarioSpec {
            name: "gen-storm".into(),
            sources: vec![
                gcrit(
                    "llama-nano",
                    Arrival::Mmpp {
                        on_hz: 300.0,
                        off_hz: 10.0,
                        mean_on_us: 3_000.0,
                        mean_off_us: 9_000.0,
                    },
                    16, 2.0, 4, 6_000.0, 3_000.0,
                ),
                gcrit(
                    "llama-nano",
                    Arrival::Uniform { rate_hz: 60.0 },
                    32, 4.0, 8, 10_000.0, 5_000.0,
                ),
                gnorm(
                    "llama-edge",
                    Arrival::Poisson { rate_hz: 60.0 },
                    96, 16.0, 32,
                ),
                gnorm(
                    "llama-edge",
                    Arrival::Mmpp {
                        on_hz: 200.0,
                        off_hz: 5.0,
                        mean_on_us: 5_000.0,
                        mean_off_us: 10_000.0,
                    },
                    160, 24.0, 48,
                ),
            ],
            duration_us,
            seed: 0x6E3,
            kv_budget_bytes: 409_600.0,
        },
    ]
}

/// The differential-test scenario (ISSUE 10 satellite): every tenant
/// draws exactly one output token (`mean_output == 1.0` makes the
/// geometric draw degenerate), so a request is pure prefill and the
/// decode machinery is provably inert — the run must reproduce the
/// fixed-chain equivalent ([`GenScenarioSpec::base_workload`] under the
/// batch driver) bitwise. Kept out of [`gen_family`] so grid baselines
/// are untouched; reachable by name.
pub fn gen_diff(duration_us: f64) -> GenScenarioSpec {
    GenScenarioSpec {
        name: "gen-diff".into(),
        sources: vec![
            gcrit(
                "llama-nano",
                Arrival::Poisson { rate_hz: 80.0 },
                24, 1.0, 1, 20_000.0, 10_000.0,
            ),
            gnorm(
                "llama-edge",
                Arrival::Poisson { rate_hz: 60.0 },
                64, 1.0, 1,
            ),
        ],
        duration_us,
        seed: 0x6E4,
        kv_budget_bytes: 8.0 * 1024.0 * 1024.0,
    }
}

/// Look up a generation scenario by name (case-insensitive): the
/// [`gen_family`] members plus the standalone [`gen_diff`] scenario.
pub fn gen_by_name(name: &str, duration_us: f64) -> Option<GenScenarioSpec> {
    gen_family(duration_us)
        .into_iter()
        .chain(std::iter::once(gen_diff(duration_us)))
        .find(|s| s.name.eq_ignore_ascii_case(name))
}

/// Pinned (scenario, scheduler) generation cells whose canonical engine
/// traces are golden files under `rust/tests/golden/gen/` — recorded by
/// the same `miriam scenarios --record-golden` flow as the main set, at
/// [`crate::workloads::scenario::GOLDEN_DURATION_US`] on
/// [`crate::workloads::scenario::GOLDEN_PLATFORM`].
pub const GEN_GOLDEN_CELLS: [(&str, &str); 4] = [
    ("gen-duo", "miriam"),
    ("gen-duo", "sequential"),
    ("gen-pressure", "miriam"),
    ("gen-pressure", "sequential"),
];

/// Subdirectory of the golden dir holding the generation anchors
/// (`rust/tests/golden/gen/`), with its own bootstrap state like
/// `devices/`.
pub const GEN_GOLDEN_SUBDIR: &str = "gen";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_and_dims_are_consistent() {
        for name in GEN_MODELS {
            let m = gen_model_by_name(name).unwrap();
            assert_eq!(m.name, name);
            assert_eq!(m.hidden, m.n_heads * m.head_dim, "{name}");
            assert!(m.n_kv_heads <= m.n_heads, "{name}");
            assert!(m.kv_bytes_per_token() > 0.0, "{name}");
        }
        assert!(gen_model_by_name("gpt-oss").is_none());
    }

    #[test]
    fn graphs_are_well_formed_and_bucketed() {
        let m = gen_model_by_name("llama-edge").unwrap();
        let g = m.prefill_graph(100);
        assert_eq!(g.kernels.len(), 5);
        // 100 rounds up to the 128 bucket; names carry the bucket.
        assert!(g.kernels[0].name.contains("/p128/"), "{}", g.kernels[0].name);
        assert_eq!(
            g.kernels.iter().map(|k| k.name.clone()).collect::<Vec<_>>(),
            m.prefill_graph(128)
                .kernels
                .iter()
                .map(|k| k.name.clone())
                .collect::<Vec<_>>(),
            "same bucket must produce identical kernel names"
        );
        for k in &g.kernels {
            assert!(k.grid >= 1 && k.flops > 0.0 && k.bytes > 0.0, "{}", k.name);
        }
        let d = m.decode_graph(40);
        assert_eq!(d.kernels.len(), 5);
        assert!(d.kernels[1].name.contains("/d64/"), "{}", d.kernels[1].name);
    }

    #[test]
    fn decode_attention_grows_with_kv_length() {
        let m = gen_model_by_name("llama-edge").unwrap();
        let short = m.decode_graph(32);
        let long = m.decode_graph(480);
        // Kernel 1 is attention: cost and footprint must grow.
        assert!(long.kernels[1].flops > short.kernels[1].flops);
        assert!(long.kernels[1].bytes > short.kernels[1].bytes);
        assert!(long.kernels[1].grid >= short.kernels[1].grid);
        // Non-attention decode kernels are KV-independent.
        for i in [0usize, 2, 3, 4] {
            assert_eq!(
                long.kernels[i].flops.to_bits(),
                short.kernels[i].flops.to_bits(),
                "kernel {i}"
            );
        }
    }

    #[test]
    fn batched_decode_scales_and_b1_is_plain() {
        let m = gen_model_by_name("llama-edge").unwrap();
        let plain = m.decode_graph(64);
        let b1 = m.decode_graph_batched(64, 1);
        for (a, b) in plain.kernels.iter().zip(&b1.kernels) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.flops.to_bits(), b.flops.to_bits());
        }
        let b4 = m.decode_graph_batched(64, 4);
        for (a, b) in plain.kernels.iter().zip(&b4.kernels) {
            assert!(b.name.contains("/b4/"), "{}", b.name);
            assert_eq!(b.grid, a.grid * 4);
            assert!((b.flops - 4.0 * a.flops).abs() < 1e-6 * a.flops);
        }
    }

    #[test]
    fn output_draws_are_seeded_bounded_and_mean_one_is_degenerate() {
        let fam = gen_family(100_000.0);
        let s = &fam[1].sources[1]; // long-generation tenant
        for ord in 0..200u64 {
            let seed = request_seed(fam[1].seed, 1, ord);
            let a = s.draw_output_len(seed);
            let b = s.draw_output_len(seed);
            assert_eq!(a, b, "draw not deterministic");
            assert!((1..=s.max_output).contains(&a), "{a}");
        }
        // Different ordinals produce different lengths somewhere.
        let mut distinct = std::collections::BTreeSet::new();
        for ord in 0..50u64 {
            distinct.insert(s.draw_output_len(request_seed(7, 1, ord)));
        }
        assert!(distinct.len() > 1, "degenerate draw distribution");
        // mean 1.0 => always exactly 1 token (the differential lever).
        let d = gen_diff(100_000.0);
        for src in 0..d.sources.len() {
            for ord in 0..100u64 {
                let seed = request_seed(d.seed, src, ord);
                assert_eq!(d.sources[src].draw_output_len(seed), 1);
            }
        }
    }

    #[test]
    fn family_validates_and_mixes_criticality() {
        let fam = gen_family(100_000.0);
        assert!(fam.len() >= 3);
        let mut names: Vec<&str> = fam.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), fam.len(), "duplicate gen scenario names");
        for sc in &fam {
            sc.validate().unwrap();
            assert!(sc.criticals() >= 1, "{}", sc.name);
            assert!(sc.criticals() < sc.tenants(), "{}", sc.name);
            assert!(sc.tenant_label(0).starts_with("t0-"), "{}", sc.name);
        }
        gen_diff(100_000.0).validate().unwrap();
    }

    #[test]
    fn base_workload_shares_graphs_and_carries_ttft_deadlines() {
        let sc = &gen_family(100_000.0)[1]; // gen-pressure: t1/t2 same bucket
        let wl = sc.base_workload();
        assert_eq!(wl.sources.len(), sc.tenants());
        assert_eq!(wl.seed, sc.seed);
        assert!(Arc::ptr_eq(&wl.sources[1].model, &wl.sources[2].model),
                "same (model, prompt bucket) must share one ModelRef");
        assert_eq!(wl.sources[0].deadline_us,
                   sc.sources[0].ttft_deadline_us);
        assert_eq!(wl.sources[1].deadline_us, None);
    }

    #[test]
    fn admission_workload_splits_prefill_from_expected_work() {
        let sc = &gen_family(100_000.0)[0];
        let wl = sc.admission_workload();
        let crit_work: f64 = wl.sources[0].model.total_flops();
        let norm_work: f64 = wl.sources[1].model.total_flops();
        let norm_prefill = gen_model_by_name("llama-edge")
            .unwrap()
            .prefill_graph(sc.sources[1].prompt_len)
            .total_flops();
        // Normals are sized by prefill + expected decode; criticals by
        // prefill alone (TTFT-binding).
        assert!(norm_work > norm_prefill, "{norm_work} vs {norm_prefill}");
        let crit_prefill = gen_model_by_name("llama-nano")
            .unwrap()
            .prefill_graph(sc.sources[0].prompt_len)
            .total_flops();
        assert_eq!(crit_work.to_bits(), crit_prefill.to_bits());
    }

    #[test]
    fn solo_criticals_preserves_criticals_and_silences_normals() {
        for sc in gen_family(100_000.0) {
            let solo = sc.solo_criticals();
            solo.validate().unwrap();
            assert_eq!(solo.tenants(), sc.tenants());
            for (i, (a, b)) in
                sc.sources.iter().zip(&solo.sources).enumerate()
            {
                assert_eq!(a.criticality, b.criticality, "{} t{i}", sc.name);
                if a.criticality == Criticality::Critical {
                    assert_eq!(format!("{:?}", a.arrival),
                               format!("{:?}", b.arrival));
                } else {
                    let empty = matches!(
                        &b.arrival,
                        Arrival::Replay { times } if times.is_empty()
                    );
                    assert!(empty, "{} t{i} normal not silenced", sc.name);
                }
            }
        }
    }

    #[test]
    fn golden_cells_resolve() {
        for (sc, sched) in GEN_GOLDEN_CELLS {
            assert!(gen_by_name(sc, 40_000.0).is_some(), "{sc}");
            assert!(crate::coordinator::is_scheduler_name(sched), "{sched}");
        }
        assert!(gen_by_name("GEN-DUO", 1e5).is_some());
        assert!(gen_by_name("duo-burst", 1e5).is_none());
    }

    #[test]
    fn request_seeds_are_stable_per_source_and_ordinal() {
        assert_eq!(request_seed(9, 0, 0), request_seed(9, 0, 0));
        assert_ne!(request_seed(9, 0, 0), request_seed(9, 0, 1));
        assert_ne!(request_seed(9, 0, 0), request_seed(9, 1, 0));
        assert_ne!(request_seed(9, 0, 0), request_seed(10, 0, 0));
    }
}
