//! Deterministic xorshift64* RNG — no external dependency, reproducible
//! across runs (benchmark workloads must be identical between schedulers).

/// xorshift64* generator (Vigna 2016). Never yields 0 state.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded generator (seed 0 is mapped to 1: xorshift state must be
    /// nonzero).
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.max(1) }
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Exponential with rate `lambda` (events per unit time).
    pub fn next_exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = 1.0 - self.next_f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Uniform integer in [0, n).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        self.next_u64() % n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn exp_mean_close_to_inverse_rate() {
        let mut r = Rng::new(13);
        let lambda = 0.01; // mean 100
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_exp(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 100.0).abs() < 5.0, "mean {mean}");
    }

    #[test]
    fn zero_seed_does_not_stick() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
        assert_ne!(r.next_u64(), r.next_u64());
    }

    #[test]
    fn f64_mean_near_half_over_10k_draws() {
        // sd of the mean of 10k U(0,1) draws is ~0.0029; 0.015 is ~5 sd.
        let mut r = Rng::new(0x5EED);
        let n = 10_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.015, "mean {mean}");
    }

    #[test]
    fn next_below_is_roughly_uniform() {
        // 8 buckets x 8000 draws: expected 1000 per bucket, sd ~30;
        // +-150 is 5 sd.
        let mut r = Rng::new(0xB0C);
        let mut buckets = [0u32; 8];
        for _ in 0..8_000 {
            buckets[r.next_below(8) as usize] += 1;
        }
        for (i, b) in buckets.iter().enumerate() {
            assert!(
                (850..=1150).contains(b),
                "bucket {i} has {b} of 8000 draws"
            );
        }
    }
}
