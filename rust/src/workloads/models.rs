//! Per-layer kernel descriptors for the paper's DNN workloads.
//!
//! The simulator schedules kernels by launch geometry + aggregate work
//! (FLOPs / DRAM bytes), so each model is described by the kernels its
//! layers launch, derived from the real layer shapes — the same
//! full-size models the paper's Tango-based MDTB uses (AlexNet,
//! SqueezeNet, GRU, LSTM, ResNet, CifarNet; §8.1.2) plus VGG16 and
//! ResNet50 for the Fig. 2 motivation experiment.
//!
//! Launch-geometry convention (Tango-style direct kernels): 256-thread
//! blocks, each thread producing `WPT` output elements; pooling and other
//! bandwidth-bound layers get their true byte traffic and tiny FLOP
//! counts. The *mini* variants actually executed by the PJRT runtime live
//! in `python/compile/model.py`; descriptor models here drive the
//! scheduling experiments at paper scale.

use std::sync::Arc;


use crate::gpu::kernel::KernelDesc;

/// Threads per block for generated conv kernels (Tango-style naive direct
/// convolutions use fat blocks).
const TPB_CONV: u32 = 512;
/// Threads per block for bandwidth-bound kernels (pool/fc/rnn).
const TPB: u32 = 256;
/// Output elements per thread (work coarsening).
const WPT: u32 = 8;
/// Compute efficiency of Tango-style naive CUDA conv kernels relative to
/// peak FP32 (no tensor cores, poor reuse): the paper's benchmark kernels
/// are direct convolutions, roughly an order of magnitude off cuDNN.
/// `flops` in a descriptor is *effective* work (time-determining), i.e.
/// theoretical FLOPs / CONV_EFF. Calibrated so AlexNet solo latency on the
/// rtx2060 preset lands in the paper's few-ms range (EXPERIMENTS.md §Calib).
const CONV_EFF: f64 = 0.08;
/// Achieved DRAM-bandwidth efficiency of naive strided accesses.
const MEM_EFF: f64 = 0.55;

/// A model = named sequence of dependent kernels.
#[derive(Debug, Clone)]
pub struct ModelDesc {
    /// Model name (e.g. "alexnet").
    pub name: String,
    /// The kernels one inference launches, in dependency order.
    pub kernels: Vec<KernelDesc>,
}

/// Shared handle to a model descriptor (cloned per request, never deep).
pub type ModelRef = Arc<ModelDesc>;

impl ModelDesc {
    /// Total FLOPs of one inference.
    pub fn total_flops(&self) -> f64 {
        self.kernels.iter().map(|k| k.flops).sum()
    }
    /// Total DRAM bytes of one inference.
    pub fn total_bytes(&self) -> f64 {
        self.kernels.iter().map(|k| k.bytes).sum()
    }
    /// Total thread blocks of one inference.
    pub fn total_blocks(&self) -> u64 {
        self.kernels.iter().map(|k| k.grid as u64).sum()
    }

    /// Intern every kernel's name through `intern` (typically
    /// [`crate::gpu::engine::Engine::intern_name`]), returning per-kernel
    /// ids parallel to `kernels`. The driver calls this once per source at
    /// workload load, so requests carry pre-interned `u32` ids and the
    /// per-request scheduling path never hashes a kernel-name `String`
    /// (ISSUE 3 zero-clone fast path).
    pub fn intern_kernels(&self, mut intern: impl FnMut(&str) -> u32)
                          -> Vec<u32> {
        self.kernels.iter().map(|k| intern(&k.name)).collect()
    }
}

fn grid_for(out_elems: u64, tpb: u32) -> u32 {
    (out_elems.div_ceil((tpb * WPT) as u64)).max(1) as u32
}

/// Convolution layer kernel. `h, w, cin` input dims; `k` square kernel,
/// stride `s`, SAME-ish padding, `cout` filters. ReLU fused (free).
fn conv(model: &str, idx: usize, h: u64, w: u64, cin: u64, cout: u64, k: u64,
        s: u64) -> (KernelDesc, u64, u64) {
    let oh = h.div_ceil(s);
    let ow = w.div_ceil(s);
    let out = oh * ow * cout;
    // Effective work: theoretical FLOPs inflated by the naive-kernel
    // inefficiency (see CONV_EFF).
    let flops = 2.0 * out as f64 * (k * k * cin) as f64 / CONV_EFF;
    let bytes = 4.0 * (h * w * cin + k * k * cin * cout + out) as f64 / MEM_EFF;
    let desc = KernelDesc {
        name: format!("{model}/conv{idx}"),
        grid: grid_for(out, TPB_CONV),
        block_threads: TPB_CONV,
        smem_per_block: ((k * k * cin * 4).min(16 * 1024)) as u32,
        regs_per_thread: 48,
        flops,
        bytes,
    };
    (desc, oh, ow)
}

/// 2x2 (or kxk) max-pool kernel: bandwidth-bound.
fn pool(model: &str, idx: usize, h: u64, w: u64, c: u64, k: u64)
        -> (KernelDesc, u64, u64) {
    let oh = h / k;
    let ow = w / k;
    let out = oh * ow * c;
    let desc = KernelDesc {
        name: format!("{model}/pool{idx}"),
        grid: grid_for(out, TPB),
        block_threads: TPB,
        smem_per_block: 0,
        regs_per_thread: 24,
        flops: (out * k * k) as f64 / CONV_EFF, // comparisons
        bytes: 4.0 * (h * w * c + out) as f64 / MEM_EFF,
    };
    (desc, oh, ow)
}

/// Fully-connected layer kernel (batch 1): memory-bound GEMV.
fn fc(model: &str, idx: usize, din: u64, dout: u64) -> KernelDesc {
    KernelDesc {
        name: format!("{model}/fc{idx}"),
        grid: grid_for(dout * 16, TPB), // GEMV rows split across threads
        block_threads: TPB,
        smem_per_block: 4 * 1024,
        regs_per_thread: 32,
        flops: 2.0 * (din * dout) as f64 / CONV_EFF,
        bytes: 4.0 * (din * dout + din + dout) as f64 / MEM_EFF,
    }
}

/// Recurrent timestep kernels: Tango-style RNN cells launch separate
/// kernels for the input GEMV, the recurrent GEMV, and the gate
/// elementwise — a long stream of small launches whose cumulative launch
/// overhead and per-launch contention is what makes RNN critical tasks
/// fragile under co-running (paper MDTB C/D).
fn rnn_step(model: &str, t: usize, input: u64, hidden: u64, gates: u64)
            -> Vec<KernelDesc> {
    let dout = gates * hidden;
    let gemv = |name: String, din: u64| KernelDesc {
        name,
        grid: grid_for(dout * 8, TPB),
        block_threads: TPB,
        smem_per_block: 2 * 1024,
        regs_per_thread: 32,
        flops: 2.0 * (din * dout) as f64 / CONV_EFF,
        bytes: 4.0 * (din * dout + din + dout) as f64 / MEM_EFF,
    };
    vec![
        gemv(format!("{model}/xw{t}"), input),
        gemv(format!("{model}/hw{t}"), hidden),
        KernelDesc {
            name: format!("{model}/gate{t}"),
            grid: grid_for(dout, TPB),
            block_threads: TPB,
            smem_per_block: 0,
            regs_per_thread: 24,
            flops: (8 * dout) as f64 / CONV_EFF,
            bytes: 4.0 * (3 * dout) as f64 / MEM_EFF,
        },
    ]
}

/// AlexNet (224x224x3, paper ref [22]).
pub fn alexnet() -> ModelDesc {
    let m = "alexnet";
    let mut ks = Vec::new();
    let (k1, h, w) = conv(m, 1, 224, 224, 3, 64, 11, 4);
    ks.push(k1);
    let (p1, h, w) = pool(m, 1, h, w, 64, 2);
    ks.push(p1);
    let (k2, h, w) = conv(m, 2, h, w, 64, 192, 5, 1);
    ks.push(k2);
    let (p2, h, w) = pool(m, 2, h, w, 192, 2);
    ks.push(p2);
    let (k3, h, w) = conv(m, 3, h, w, 192, 384, 3, 1);
    ks.push(k3);
    let (k4, h, w) = conv(m, 4, h, w, 384, 256, 3, 1);
    ks.push(k4);
    let (k5, h, w) = conv(m, 5, h, w, 256, 256, 3, 1);
    ks.push(k5);
    let (p3, h, w) = pool(m, 3, h, w, 256, 2);
    ks.push(p3);
    ks.push(fc(m, 1, h * w * 256, 4096));
    ks.push(fc(m, 2, 4096, 4096));
    ks.push(fc(m, 3, 4096, 1000));
    ModelDesc { name: m.into(), kernels: ks }
}

/// CifarNet (32x32x3, paper ref [30]).
pub fn cifarnet() -> ModelDesc {
    let m = "cifarnet";
    let mut ks = Vec::new();
    let (k1, h, w) = conv(m, 1, 32, 32, 3, 64, 5, 1);
    ks.push(k1);
    let (p1, h, w) = pool(m, 1, h, w, 64, 2);
    ks.push(p1);
    let (k2, h, w) = conv(m, 2, h, w, 64, 64, 5, 1);
    ks.push(k2);
    let (p2, h, w) = pool(m, 2, h, w, 64, 2);
    ks.push(p2);
    ks.push(fc(m, 1, h * w * 64, 384));
    ks.push(fc(m, 2, 384, 10));
    ModelDesc { name: m.into(), kernels: ks }
}

/// SqueezeNet v1.0 (224x224x3, paper ref [15]): conv1, 8 fire modules
/// (squeeze 1x1 + expand 1x1/3x3 merged per module into two kernels),
/// conv10.
pub fn squeezenet() -> ModelDesc {
    let m = "squeezenet";
    let mut ks = Vec::new();
    let (k1, mut h, mut w) = conv(m, 1, 224, 224, 3, 96, 7, 2);
    ks.push(k1);
    let (p1, h2, w2) = pool(m, 1, h, w, 96, 2);
    ks.push(p1);
    h = h2;
    w = w2;
    // (cin, squeeze, expand) per fire module; pools after fire3 and fire7.
    let fires: [(u64, u64, u64); 8] = [
        (96, 16, 64), (128, 16, 64), (128, 32, 128), (256, 32, 128),
        (256, 48, 192), (384, 48, 192), (384, 64, 256), (512, 64, 256),
    ];
    for (i, (cin, sq, ex)) in fires.iter().enumerate() {
        let (s1, _, _) = conv(m, 10 + i, h, w, *cin, *sq, 1, 1);
        ks.push(s1);
        let (e3, h3, w3) = conv(m, 20 + i, h, w, *sq, 2 * ex, 3, 1);
        ks.push(e3);
        h = h3;
        w = w3;
        if i == 2 || i == 6 {
            let (p, h2, w2) = pool(m, 2 + i, h, w, 2 * ex, 2);
            ks.push(p);
            h = h2;
            w = w2;
        }
    }
    let (k10, h, w) = conv(m, 10, h, w, 512, 1000, 1, 1);
    ks.push(k10);
    let (gap, _, _) = pool(m, 9, h, w, 1000, h.min(w).max(1));
    ks.push(gap);
    ModelDesc { name: m.into(), kernels: ks }
}

/// ResNet-18-ish (224x224x3, paper ref [13]; the paper's MDTB "ResNet").
pub fn resnet() -> ModelDesc {
    let m = "resnet";
    let mut ks = Vec::new();
    let (k1, h, w) = conv(m, 0, 224, 224, 3, 64, 7, 2);
    ks.push(k1);
    let (p1, mut h, mut w) = pool(m, 0, h, w, 64, 2);
    ks.push(p1);
    // 4 stages x 2 basic blocks x 2 convs.
    let stages: [(u64, u64); 4] = [(64, 1), (128, 2), (256, 2), (512, 2)];
    let mut cin = 64u64;
    let mut idx = 1;
    for (cout, stride) in stages {
        for b in 0..2u64 {
            let s = if b == 0 { stride } else { 1 };
            let (c1, h1, w1) = conv(m, idx, h, w, cin, cout, 3, s);
            ks.push(c1);
            idx += 1;
            let (c2, h2, w2) = conv(m, idx, h1, w1, cout, cout, 3, 1);
            ks.push(c2);
            idx += 1;
            if cin != cout {
                let (pr, _, _) = conv(m, 100 + idx, h, w, cin, cout, 1, s);
                ks.push(pr);
            }
            h = h2;
            w = w2;
            cin = cout;
        }
    }
    let (gap, _, _) = pool(m, 99, h, w, 512, h.min(w).max(1));
    ks.push(gap);
    ks.push(fc(m, 1, 512, 1000));
    ModelDesc { name: m.into(), kernels: ks }
}

/// ResNet-50 (for the Fig. 2 motivation experiment).
pub fn resnet50() -> ModelDesc {
    let m = "resnet50";
    let mut ks = Vec::new();
    let (k1, h, w) = conv(m, 0, 224, 224, 3, 64, 7, 2);
    ks.push(k1);
    let (p1, mut h, mut w) = pool(m, 0, h, w, 64, 2);
    ks.push(p1);
    let stages: [(u64, u64, u64); 4] =
        [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)];
    let mut cin = 64u64;
    let mut idx = 1;
    for (cmid, blocks, stride) in stages {
        for b in 0..blocks {
            let s = if b == 0 { stride } else { 1 };
            let cout = cmid * 4;
            let (c1, h1, w1) = conv(m, idx, h, w, cin, cmid, 1, s);
            ks.push(c1);
            idx += 1;
            let (c2, h2, w2) = conv(m, idx, h1, w1, cmid, cmid, 3, 1);
            ks.push(c2);
            idx += 1;
            let (c3, h3, w3) = conv(m, idx, h2, w2, cmid, cout, 1, 1);
            ks.push(c3);
            idx += 1;
            if cin != cout {
                let (pr, _, _) = conv(m, 100 + idx, h, w, cin, cout, 1, s);
                ks.push(pr);
            }
            h = h3;
            w = w3;
            cin = cout;
        }
    }
    let (gap, _, _) = pool(m, 99, h, w, 2048, h.min(w).max(1));
    ks.push(gap);
    ks.push(fc(m, 1, 2048, 1000));
    ModelDesc { name: m.into(), kernels: ks }
}

/// VGG16 (Fig. 2 co-runner).
pub fn vgg16() -> ModelDesc {
    let m = "vgg16";
    let mut ks = Vec::new();
    let cfg: [(u64, u64); 13] = [
        (3, 64), (64, 64),
        (64, 128), (128, 128),
        (128, 256), (256, 256), (256, 256),
        (256, 512), (512, 512), (512, 512),
        (512, 512), (512, 512), (512, 512),
    ];
    let pool_after = [1usize, 3, 6, 9, 12];
    let (mut h, mut w) = (224u64, 224u64);
    for (i, (cin, cout)) in cfg.iter().enumerate() {
        let (c, h1, w1) = conv(m, i + 1, h, w, *cin, *cout, 3, 1);
        ks.push(c);
        h = h1;
        w = w1;
        if pool_after.contains(&i) {
            let (p, h2, w2) = pool(m, i, h, w, *cout, 2);
            ks.push(p);
            h = h2;
            w = w2;
        }
    }
    ks.push(fc(m, 1, h * w * 512, 4096));
    ks.push(fc(m, 2, 4096, 4096));
    ks.push(fc(m, 3, 4096, 1000));
    ModelDesc { name: m.into(), kernels: ks }
}

/// GRU (paper ref [7]): 128 timesteps, input 128, hidden 256, 3 launches
/// per step — a launch-overhead-dominated critical task, the profile that
/// makes RNNs fragile under co-running (MDTB-C).
pub fn gru() -> ModelDesc {
    let m = "gru";
    let mut ks: Vec<KernelDesc> =
        (0..128).flat_map(|t| rnn_step(m, t, 128, 256, 3)).collect();
    ks.push(fc(m, 1, 256, 10));
    ModelDesc { name: m.into(), kernels: ks }
}

/// LSTM (paper ref [14]): 128 timesteps, input 128, hidden 256, 3 launches
/// per step.
pub fn lstm() -> ModelDesc {
    let m = "lstm";
    let mut ks: Vec<KernelDesc> =
        (0..128).flat_map(|t| rnn_step(m, t, 128, 256, 4)).collect();
    ks.push(fc(m, 1, 256, 10));
    ModelDesc { name: m.into(), kernels: ks }
}

/// Model registry by name.
pub fn by_name(name: &str) -> Option<ModelDesc> {
    match name {
        "alexnet" => Some(alexnet()),
        "cifarnet" => Some(cifarnet()),
        "squeezenet" => Some(squeezenet()),
        "resnet" => Some(resnet()),
        "resnet50" => Some(resnet50()),
        "vgg16" => Some(vgg16()),
        "gru" => Some(gru()),
        "lstm" => Some(lstm()),
        _ => None,
    }
}

/// All MDTB model names (paper §8.1.2).
pub const MDTB_MODELS: [&str; 6] =
    ["alexnet", "squeezenet", "gru", "lstm", "resnet", "cifarnet"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all() {
        for name in MDTB_MODELS.iter().chain(["resnet50", "vgg16"].iter()) {
            let m = by_name(name).unwrap();
            assert!(!m.kernels.is_empty(), "{name}");
            assert_eq!(m.name, *name);
        }
        assert!(by_name("bert").is_none());
    }

    #[test]
    fn kernels_are_well_formed() {
        for name in MDTB_MODELS.iter().chain(["resnet50", "vgg16"].iter()) {
            for k in by_name(name).unwrap().kernels {
                assert!(k.grid > 0, "{}", k.name);
                assert!(k.block_threads > 0 && k.block_threads <= 1024, "{}", k.name);
                assert!(k.flops > 0.0, "{}", k.name);
                assert!(k.bytes > 0.0, "{}", k.name);
                assert!(k.smem_per_block <= 48 * 1024, "{}", k.name);
            }
        }
    }

    #[test]
    fn flop_scale_sanity() {
        // Published single-inference FLOP counts (x2 for MAC->FLOP):
        // AlexNet ~1.4 GFLOP, VGG16 ~31 GFLOP, ResNet50 ~8 GFLOP — stored
        // values are *effective* (theoretical / CONV_EFF), so the expected
        // windows scale by 1/CONV_EFF = 12.5.
        let a = alexnet().total_flops();
        assert!((1.0e9 / CONV_EFF..3.0e9 / CONV_EFF).contains(&a),
                "alexnet {a:.2e}");
        let v = vgg16().total_flops();
        assert!((2.0e10 / CONV_EFF..4.0e10 / CONV_EFF).contains(&v),
                "vgg16 {v:.2e}");
        let r = resnet50().total_flops();
        assert!((6.0e9 / CONV_EFF..1.2e10 / CONV_EFF).contains(&r),
                "resnet50 {r:.2e}");
    }

    #[test]
    fn relative_model_weight() {
        // The paper's workload mix relies on these orderings.
        assert!(vgg16().total_flops() > resnet50().total_flops());
        assert!(resnet50().total_flops() > alexnet().total_flops());
        assert!(alexnet().total_flops() > cifarnet().total_flops());
        // SqueezeNet trades parameters, not FLOPs: its per-inference work
        // is comparable to AlexNet's (~1.7 vs ~1.4 GFLOP theoretical).
        assert!(squeezenet().total_flops() < resnet50().total_flops());
        assert!(lstm().total_flops() > gru().total_flops());
    }

    #[test]
    fn intern_kernels_is_parallel_and_order_stable() {
        let m = cifarnet();
        let mut seen: Vec<String> = Vec::new();
        let ids = m.intern_kernels(|n| {
            if let Some(i) = seen.iter().position(|s| s == n) {
                i as u32
            } else {
                seen.push(n.to_string());
                (seen.len() - 1) as u32
            }
        });
        assert_eq!(ids.len(), m.kernels.len());
        for (k, &id) in m.kernels.iter().zip(&ids) {
            assert_eq!(seen[id as usize], k.name);
        }
    }

    #[test]
    fn grids_give_simulation_scale() {
        // Keep per-inference block counts in a range the event-driven
        // simulator sweeps in milliseconds (DESIGN.md §5).
        for name in MDTB_MODELS {
            let blocks = by_name(name).unwrap().total_blocks();
            assert!(blocks >= 10, "{name} {blocks}");
            assert!(blocks <= 50_000, "{name} {blocks}");
        }
    }
}
