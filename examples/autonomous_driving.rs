//! Autonomous-driving scenario (the paper's §8.5 case study, Fig. 11/12):
//! replay a regenerated LGSVL perception trace — camera-driven obstacle
//! detection (ResNet backbone, critical, 10 Hz) and lidar-driven pose
//! estimation (SqueezeNet backbone, normal, 12.5 Hz) — through each
//! scheduler and report whether the critical task would hold a 100 ms
//! perception deadline.
//!
//! Run: `cargo run --release --example autonomous_driving`

use miriam::coordinator::{driver, scheduler_for, SCHEDULERS};
use miriam::gpu::spec::GpuSpec;
use miriam::workloads::lgsvl;

fn main() {
    let duration_us = 3_000_000.0;
    let deadline_ms = 100.0;
    let spec = GpuSpec::rtx2060();
    let wl = lgsvl::workload(duration_us);

    println!("LGSVL perception workload, {}s simulated on {}",
             duration_us / 1e6, spec.name);
    println!("critical: {} @10Hz | normal: {} @12.5Hz\n",
             wl.sources[0].model.name, wl.sources[1].model.name);

    println!("{:<12} {:>10} {:>10} {:>12} {:>10} {:>12}",
             "scheduler", "crit(ms)", "p99(ms)", "tput(req/s)", "occup",
             "deadline ok");
    for name in SCHEDULERS {
        let mut sched = scheduler_for(name, &wl).expect("scheduler");
        let st = driver::run(spec.clone(), &wl, sched.as_mut());
        let viol = st
            .critical_latencies_us
            .iter()
            .filter(|l| **l > deadline_ms * 1e3)
            .count();
        println!("{:<12} {:>10.2} {:>10.2} {:>12.1} {:>10.3} {:>11}",
                 name,
                 st.critical_latency_mean_us() / 1e3,
                 st.critical_latency_p99_us() / 1e3,
                 st.throughput_rps(),
                 st.achieved_occupancy,
                 if viol == 0 {
                     "yes".to_string()
                 } else {
                     format!("{viol} misses")
                 });
    }
    println!("\nThe trace itself (sensor arrivals with timestamp jitter):");
    for (t, src) in lgsvl::trace(400_000.0, 1_500.0, wl.seed).iter().take(10) {
        println!("  {:>8.2} ms  {}", t / 1e3,
                 if *src == 0 { "camera frame -> obstacle detection (CRITICAL)" }
                 else { "lidar sweep  -> pose estimation  (normal)" });
    }
}
