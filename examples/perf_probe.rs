//! Perf probe: simulator event throughput on a heavy cell (not shipped as
//! a bench; used by the EXPERIMENTS.md §Perf log).
use miriam::coordinator::{driver, scheduler_for};
use miriam::gpu::spec::GpuSpec;
use miriam::workloads::mdtb;

fn main() {
    for (wl_name, sched) in [("D", "multistream"), ("D", "miriam"),
                             ("A", "multistream"), ("C", "miriam")] {
        let wl = mdtb::by_name(wl_name, 2_000_000.0).unwrap().build();
        let mut s = scheduler_for(sched, &wl).unwrap();
        let t0 = std::time::Instant::now();
        let st = driver::run(GpuSpec::rtx2060(), &wl, s.as_mut());
        let wall = t0.elapsed().as_secs_f64();
        println!("{wl_name}/{sched:<12} events {:>8}  wall {:>6.2}s  {:>9.0} events/s  sched-decision mean {:.2}us",
                 st.events, wall, st.events as f64 / wall,
                 st.sched_decision_mean_us());
    }
}
