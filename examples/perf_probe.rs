//! Perf probe: simulator event throughput on a heavy cell (the quick
//! one-cell companion to `benches/engine_throughput.rs`; used by the
//! EXPERIMENTS.md §Perf log). Runs each cell through both rate-model
//! paths so the incremental-vs-reference speedup is visible at a glance.
use miriam::coordinator::driver::{self, RunOpts};
use miriam::coordinator::scheduler_for;
use miriam::gpu::spec::GpuSpec;
use miriam::workloads::mdtb;

fn main() {
    for (wl_name, sched) in [("D", "multistream"), ("D", "miriam"),
                             ("A", "multistream"), ("C", "miriam")] {
        let wl = mdtb::by_name(wl_name, 2_000_000.0).unwrap().build();
        let mut cell = Vec::new();
        for reference in [true, false] {
            let mut s = scheduler_for(sched, &wl).unwrap();
            let st = driver::run_with(GpuSpec::rtx2060(), &wl, s.as_mut(),
                                      RunOpts { reference_rates: reference,
                                                trace: false });
            cell.push(st.events_per_sec());
            let leg = if reference { "reference  " } else { "incremental" };
            println!("{wl_name}/{sched:<12} {leg} events {:>9}  wall {:>6.2}s  \
                      {:>10.0} events/s  sched-decision mean {:.2}us",
                     st.events, st.wall_ns as f64 / 1e9, st.events_per_sec(),
                     st.sched_decision_mean_us());
        }
        println!("{wl_name}/{sched:<12} speedup {:.2}x",
                 cell[1] / cell[0].max(1e-12));
    }
}
