//! Quickstart: the smallest end-to-end use of the library.
//!
//! Builds the MDTB-A workload (AlexNet critical + CifarNet normal, both
//! closed-loop), runs it under all four schedulers on the simulated RTX
//! 2060, and prints the paper's three metrics per scheduler.
//!
//! Run: `cargo run --release --example quickstart`

use miriam::coordinator::{driver, scheduler_for, SCHEDULERS};
use miriam::gpu::spec::GpuSpec;
use miriam::workloads::mdtb;

fn main() {
    let spec = GpuSpec::rtx2060();
    let wl = mdtb::mdtb_a(500_000.0).build(); // 0.5 simulated seconds

    println!("workload {} on {} ({} SMs)", wl.name, spec.name, spec.num_sms);
    println!("{:<12} {:>12} {:>14} {:>10}",
             "scheduler", "crit lat(ms)", "tput (req/s)", "occupancy");
    for name in SCHEDULERS {
        let mut sched = scheduler_for(name, &wl).expect("known scheduler");
        let stats = driver::run(spec.clone(), &wl, sched.as_mut());
        println!("{:<12} {:>12.2} {:>14.1} {:>10.3}",
                 name,
                 stats.critical_latency_mean_us() / 1e3,
                 stats.throughput_rps(),
                 stats.achieved_occupancy);
    }
    println!("\nExpected shape: miriam holds critical latency near (or below)");
    println!("sequential while clearly beating its throughput; multistream");
    println!("trades critical latency away for raw throughput.");
}
