//! End-to-end serving driver: load REAL models (the AOT-compiled
//! JAX/Pallas artifacts) into the PJRT CPU runtime and serve a batch of
//! mixed-criticality requests through the criticality-aware router,
//! reporting latency and throughput. This is the proof that all layers
//! compose: Pallas kernels -> JAX models -> HLO text -> Rust PJRT runtime
//! -> serving loop, with Python nowhere on the request path.
//!
//! Requires `make artifacts` to have been run.
//!
//! Run: `cargo run --release --example serve_e2e`

use std::sync::atomic::Ordering;
use std::time::Instant;

use miriam::gpu::kernel::Criticality;
use miriam::runtime::artifacts::npy_rand;
use miriam::runtime::Manifest;
use miriam::server::Server;

fn main() -> anyhow::Result<()> {
    let dir = Manifest::default_dir();
    let manifest = Manifest::load(&dir)?;

    // Verify golden numerics of every model artifact first (the §6.4
    // computational-consistency contract across the language boundary).
    println!("== artifact verification (PJRT CPU) ==");
    let mut rt = miriam::runtime::Runtime::new(manifest.clone())?;
    let models: Vec<String> = rt.model_names();
    for name in &models {
        let entry = rt.manifest.entry(name)?.clone();
        let m = rt.load(name)?;
        let n: usize = m.input_shapes[0].iter().product();
        let golden = entry.golden.as_ref().expect("model artifacts carry goldens");
        let input = npy_rand::randn(golden.input_seed as u32, n);
        let out = m.run_f32(&[input])?;
        let max_err = out
            .iter()
            .zip(&golden.output)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("  {name:<12} max|err| = {max_err:.2e}  {}",
                 if max_err < 1e-3 { "OK" } else { "MISMATCH" });
        assert!(max_err < 1e-3, "{name} numerics drifted");
    }

    // Serve a mixed-criticality request stream: cifarnet as the critical
    // task (obstacle-detection stand-in), squeezenet+gru as normal tasks.
    println!("\n== serving 300 mixed requests ==");
    let server = Server::start(&dir, &models)?;
    let handle = server.handle.clone();
    let t0 = Instant::now();
    let mut critical_lat = Vec::new();
    let mut normal_lat = Vec::new();
    for i in 0..300 {
        let (model, crit) = match i % 3 {
            0 => ("cifarnet", Criticality::Critical),
            1 => ("squeezenet", Criticality::Normal),
            _ => ("gru", Criticality::Normal),
        };
        let entry = manifest.entry(model)?;
        let n: usize = entry.inputs[0].shape.iter().product();
        let input = npy_rand::randn(42 + i as u32, n);
        let reply = handle.infer(model, crit, input);
        assert!(reply.ok, "inference failed: {:?}", reply.error);
        match crit {
            Criticality::Critical => critical_lat.push(reply.latency_us),
            Criticality::Normal => normal_lat.push(reply.latency_us),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = handle.stats.clone();
    println!("served {} critical + {} normal in {:.2}s  ({:.1} req/s)",
             stats.served_critical.load(Ordering::Relaxed),
             stats.served_normal.load(Ordering::Relaxed),
             wall, 300.0 / wall);
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!("critical latency: mean {:.2} ms | normal latency: mean {:.2} ms",
             mean(&critical_lat) / 1e3, mean(&normal_lat) / 1e3);
    assert_eq!(stats.errors.load(Ordering::Relaxed), 0);
    server.stop();
    println!("e2e OK");
    Ok(())
}
