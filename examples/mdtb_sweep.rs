//! Parameter sweep: how the scheduler ranking shifts with critical-task
//! request rate and platform size — the "beyond the paper" exploration the
//! MDTB harness enables. Sweeps the MDTB-B template (SqueezeNet critical,
//! AlexNet normal) over critical rates 2..40 Hz on both platforms.
//!
//! Run: `cargo run --release --example mdtb_sweep`

use miriam::coordinator::{driver, scheduler_for, SCHEDULERS};
use miriam::gpu::spec::GpuSpec;
use miriam::workloads::arrival::Arrival;
use miriam::workloads::mdtb::{self};

fn main() {
    let duration_us = 800_000.0;
    for spec in [GpuSpec::rtx2060(), GpuSpec::xavier()] {
        println!("\n## platform {}", spec.name);
        println!("{:>6} {:<12} {:>10} {:>12} {:>8}",
                 "rateHz", "scheduler", "crit(ms)", "tput(req/s)", "occup");
        for rate in [2.0, 5.0, 10.0, 20.0, 40.0] {
            let mut ws = mdtb::mdtb_b(duration_us);
            ws.critical_arrival = Arrival::Uniform { rate_hz: rate };
            ws.name = format!("B@{rate}Hz");
            let wl = ws.build();
            for sched in SCHEDULERS {
                let mut s = scheduler_for(sched, &wl).unwrap();
                let st = driver::run(spec.clone(), &wl, s.as_mut());
                println!("{:>6} {:<12} {:>10.2} {:>12.1} {:>8.3}",
                         rate, sched,
                         st.critical_latency_mean_us() / 1e3,
                         st.throughput_rps(),
                         st.achieved_occupancy);
            }
        }
    }
    println!("\nAs critical rate rises, the co-running window shrinks:");
    println!("multistream's latency inflation grows while miriam's shards");
    println!("keep the critical stream near its solo speed.");
}
