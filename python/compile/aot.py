"""AOT export: lower the L2 models (and elastic-kernel shards) to HLO text.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 (behind the `xla` 0.1.6 crate) rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Outputs (to --out, default ../artifacts):
  <model>.hlo.txt        — one per MDTB model, params baked as constants,
                           signature (input,) -> (logits[10],)
  matmul_rows<R>.hlo.txt — elastic-grid matmul shard executables: the full
                           (64,32)@(32,48) product sliced into 2**d equal
                           row shards shares one executable per shard size R,
                           signature (x[R,32], w[32,48]) -> (y[R,48],).
                           The Rust runtime demonstrates the paper's §6.4
                           consistency property by stitching shard outputs.
  manifest.json          — machine-readable registry (name, file, shapes,
                           golden input/output checksums) read by
                           rust/src/runtime/artifacts.rs.

Run once at build time (`make artifacts`); never on the request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_zoo
from .kernels.elastic_matmul import matmul_tiled

# The shard family exported for the runtime elasticity demo.
MM_M, MM_K, MM_N = 64, 32, 48
MM_DEGREES = [0, 1, 2, 3]  # shard row counts 64, 32, 16, 8


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the baked model weights MUST survive the
    # text round trip — the default printer elides them as `constant({...})`,
    # which the rust-side parser would reject (or worse, zero-fill).
    return comp.as_hlo_text(print_large_constants=True)


def _golden_input(shape, seed=42):
    return np.asarray(
        np.random.RandomState(seed).randn(*shape), dtype=np.float32)


def _sha16(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


def export_model(name: str, out_dir: str) -> dict:
    shape, fn = model_zoo.build(name)
    wrapped = lambda x: (fn(x),)
    spec = jax.ShapeDtypeStruct(shape, jnp.float32)
    t0 = time.time()
    lowered = jax.jit(wrapped).lower(spec)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    # Golden vector for the Rust runtime integration tests.
    gx = _golden_input(shape)
    gy = np.asarray(jax.jit(wrapped)(jnp.asarray(gx))[0])
    print(f"  {name}: {len(text) / 1e6:.2f} MB HLO text, "
          f"{time.time() - t0:.1f}s")
    return {
        "name": name,
        "file": f"{name}.hlo.txt",
        "kind": "model",
        "inputs": [{"shape": list(shape), "dtype": "f32"}],
        "outputs": [{"shape": [10], "dtype": "f32"}],
        "golden": {
            "input_seed": 42,
            "input_sha": _sha16(gx),
            "output": [float(v) for v in gy],
        },
    }


def export_matmul_shards(out_dir: str) -> list[dict]:
    entries = []
    w = _golden_input((MM_K, MM_N), seed=7)
    x = _golden_input((MM_M, MM_K), seed=8)
    full = np.asarray(x @ w, dtype=np.float32)
    for d in MM_DEGREES:
        rows = MM_M // (2 ** d)
        fn = lambda xs, ws: (matmul_tiled(xs, ws, bm=min(16, rows), bn=16),)
        spec_x = jax.ShapeDtypeStruct((rows, MM_K), jnp.float32)
        spec_w = jax.ShapeDtypeStruct((MM_K, MM_N), jnp.float32)
        text = to_hlo_text(jax.jit(fn).lower(spec_x, spec_w))
        fname = f"matmul_rows{rows}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append({
            "name": f"matmul_rows{rows}",
            "file": fname,
            "kind": "matmul_shard",
            "degree": d,
            "rows": rows,
            "inputs": [
                {"shape": [rows, MM_K], "dtype": "f32"},
                {"shape": [MM_K, MM_N], "dtype": "f32"},
            ],
            "outputs": [{"shape": [rows, MM_N], "dtype": "f32"}],
        })
        print(f"  matmul_rows{rows}: degree {d}")
    # One golden product for all degrees (shards must stitch back to this).
    entries.append({
        "name": "matmul_golden",
        "kind": "golden",
        "m": MM_M, "k": MM_K, "n": MM_N,
        "x_seed": 8, "w_seed": 7,
        "output_sha": _sha16(full),
        "output_first8": [float(v) for v in full.ravel()[:8]],
    })
    return entries


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(model_zoo.MODELS),
                    help="comma-separated subset to export")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"version": 1, "artifacts": []}
    print("exporting matmul shard family:")
    manifest["artifacts"] += export_matmul_shards(args.out)
    for name in args.models.split(","):
        print(f"exporting model {name}:")
        manifest["artifacts"].append(export_model(name, args.out))

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {args.out}/manifest.json "
          f"({len(manifest['artifacts'])} entries)")


if __name__ == "__main__":
    main()
