"""L2: the MDTB model zoo as JAX forward functions calling the L1 kernels.

Six models matching the paper's MDTB benchmark (Table 2 / §8.1.2): AlexNet,
SqueezeNet, GRU, LSTM, ResNet, CifarNet. They are "-mini" width/depth
variants (the paper's CUDA Tango kernels target a 2060; our CPU-PJRT
substitution keeps parameter counts small so the AOT HLO-text artifacts stay
tractable) but preserve each model's characteristic kernel mix — conv-heavy
(AlexNet/CifarNet), 1x1+3x3 fire modules (SqueezeNet), residual blocks
(ResNet), and GEMM-recurrent cells (GRU/LSTM) — which is what drives the
kernel-descriptor workloads on the Rust side.

Every dense contraction goes through the elastic Pallas kernels
(kernels.elastic_matmul / kernels.elastic_conv), so the AOT artifacts
exercise the L1 hot path end to end. Elementwise/pooling glue is plain jnp.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import ref
from .kernels.elastic_conv import conv2d_elastic, conv2d_same_elastic
from .kernels.elastic_matmul import matmul_persistent


def _mm(x, w):
    """All model GEMMs route through the elastic persistent-thread kernel."""
    return matmul_persistent(x, w, num_programs=4, block_m=16)


def _linear(x, w, b):
    return _mm(x, w) + b


def _conv_same(x, w):
    return conv2d_same_elastic(x, w, block_rows=4, block_co=16)


def _conv_valid(x, w):
    return conv2d_elastic(x, w, block_rows=4, block_co=16)


def _relu(x):
    return jnp.maximum(x, 0.0)


def _pool2(x):
    h, w, c = x.shape
    return x.reshape(h // 2, 2, w // 2, 2, c).max(axis=(1, 3))


def _gap(x):
    return x.mean(axis=(0, 1))


# ---------------------------------------------------------------------------
# Parameter initialization: deterministic, He-scaled.
# ---------------------------------------------------------------------------

def _init(key, shape, fan_in):
    return (jax.random.normal(key, shape, jnp.float32)
            * jnp.sqrt(2.0 / fan_in)).astype(jnp.float32)


def _conv_p(key, kh, kw, cin, cout):
    return _init(key, (kh, kw, cin, cout), kh * kw * cin)


def _fc_p(key, din, dout):
    k1, _ = jax.random.split(key)
    return (_init(k1, (din, dout), din), jnp.zeros((dout,), jnp.float32))


# ---------------------------------------------------------------------------
# CifarNet (paper ref [30]) — small 2-conv CNN, 32x32x3 input.
# ---------------------------------------------------------------------------

def cifarnet_init(seed: int = 0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {
        "c1": _conv_p(ks[0], 5, 5, 3, 16),
        "c2": _conv_p(ks[1], 5, 5, 16, 32),
        "f1": _fc_p(ks[2], 8 * 8 * 32, 64),
        "f2": _fc_p(ks[3], 64, 10),
    }


def cifarnet_forward(p, x):
    """x: (32, 32, 3) -> logits (10,)."""
    x = _pool2(_relu(_conv_same(x, p["c1"])))
    x = _pool2(_relu(_conv_same(x, p["c2"])))
    x = x.reshape(1, -1)
    x = _relu(_linear(x, *p["f1"]))
    return _linear(x, *p["f2"])[0]


# ---------------------------------------------------------------------------
# AlexNet-mini (paper ref [22]) — 5 convs + 3 FCs, 64x64x3 input.
# ---------------------------------------------------------------------------

def alexnet_init(seed: int = 1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    return {
        "c1": _conv_p(ks[0], 5, 5, 3, 16),
        "c2": _conv_p(ks[1], 5, 5, 16, 32),
        "c3": _conv_p(ks[2], 3, 3, 32, 48),
        "c4": _conv_p(ks[3], 3, 3, 48, 48),
        "c5": _conv_p(ks[4], 3, 3, 48, 32),
        "f1": _fc_p(ks[5], 8 * 8 * 32, 128),
        "f2": _fc_p(ks[6], 128, 64),
        "f3": _fc_p(ks[7], 64, 10),
    }


def alexnet_forward(p, x):
    """x: (64, 64, 3) -> logits (10,)."""
    x = _pool2(_relu(_conv_same(x, p["c1"])))          # 32x32x16
    x = _pool2(_relu(_conv_same(x, p["c2"])))          # 16x16x32
    x = _relu(_conv_same(x, p["c3"]))                  # 16x16x48
    x = _relu(_conv_same(x, p["c4"]))                  # 16x16x48
    x = _pool2(_relu(_conv_same(x, p["c5"])))          # 8x8x32
    x = x.reshape(1, -1)
    x = _relu(_linear(x, *p["f1"]))
    x = _relu(_linear(x, *p["f2"]))
    return _linear(x, *p["f3"])[0]


# ---------------------------------------------------------------------------
# SqueezeNet-mini (paper ref [15]) — fire modules, 64x64x3 input.
# ---------------------------------------------------------------------------

def _fire_p(key, cin, squeeze, expand):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "s1": _conv_p(k1, 1, 1, cin, squeeze),
        "e1": _conv_p(k2, 1, 1, squeeze, expand),
        "e3": _conv_p(k3, 3, 3, squeeze, expand),
    }


def _fire(p, x):
    s = _relu(_conv_same(x, p["s1"]))
    return jnp.concatenate(
        [_relu(_conv_same(s, p["e1"])), _relu(_conv_same(s, p["e3"]))], axis=-1)


def squeezenet_init(seed: int = 2):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    return {
        "c1": _conv_p(ks[0], 3, 3, 3, 16),
        "fire1": _fire_p(ks[1], 16, 4, 16),
        "fire2": _fire_p(ks[2], 32, 4, 16),
        "fire3": _fire_p(ks[3], 32, 8, 24),
        "c2": _conv_p(ks[4], 1, 1, 48, 10),
    }


def squeezenet_forward(p, x):
    """x: (64, 64, 3) -> logits (10,)."""
    x = _pool2(_relu(_conv_same(x, p["c1"])))          # 32x32x16
    x = _fire(p["fire1"], x)                           # 32x32x32
    x = _pool2(_fire(p["fire2"], x))                   # 16x16x32
    x = _pool2(_fire(p["fire3"], x))                   # 8x8x48
    x = _conv_same(x, p["c2"])                         # 8x8x10
    return _gap(x)


# ---------------------------------------------------------------------------
# ResNet-8-mini (paper ref [13]) — 3 residual blocks, 32x32x3 input.
# ---------------------------------------------------------------------------

def _res_p(key, cin, cout):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"c1": _conv_p(k1, 3, 3, cin, cout), "c2": _conv_p(k2, 3, 3, cout, cout)}
    if cin != cout:
        p["proj"] = _conv_p(k3, 1, 1, cin, cout)
    return p


def _res_block(p, x):
    y = _relu(_conv_same(x, p["c1"]))
    y = _conv_same(y, p["c2"])
    sc = _conv_same(x, p["proj"]) if "proj" in p else x
    return _relu(sc + y)


def resnet_init(seed: int = 3):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    return {
        "c1": _conv_p(ks[0], 3, 3, 3, 16),
        "b1": _res_p(ks[1], 16, 16),
        "b2": _res_p(ks[2], 16, 32),
        "b3": _res_p(ks[3], 32, 32),
        "fc": _fc_p(ks[4], 32, 10),
    }


def resnet_forward(p, x):
    """x: (32, 32, 3) -> logits (10,)."""
    x = _relu(_conv_same(x, p["c1"]))                  # 32x32x16
    x = _res_block(p["b1"], x)                         # 32x32x16
    x = _pool2(_res_block(p["b2"], x))                 # 16x16x32
    x = _pool2(_res_block(p["b3"], x))                 # 8x8x32
    x = _gap(x).reshape(1, -1)
    return _linear(x, *p["fc"])[0]


# ---------------------------------------------------------------------------
# GRU / LSTM (paper refs [7], [14]) — GEMM-recurrent, seq 16 x feature 32.
# ---------------------------------------------------------------------------

GRU_T, GRU_I, GRU_H = 16, 32, 64


def gru_init(seed: int = 4):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {
        "wx": _init(ks[0], (GRU_I, 3 * GRU_H), GRU_I),
        "wh": _init(ks[1], (GRU_H, 3 * GRU_H), GRU_H),
        "b": jnp.zeros((3 * GRU_H,), jnp.float32),
        "fc": _fc_p(ks[2], GRU_H, 10),
    }


def _gru_cell(p, h, x):
    hsz = h.shape[-1]
    gx = _mm(x, p["wx"]) + p["b"]
    gh = _mm(h, p["wh"])
    r = jax.nn.sigmoid(gx[:, :hsz] + gh[:, :hsz])
    z = jax.nn.sigmoid(gx[:, hsz:2 * hsz] + gh[:, hsz:2 * hsz])
    n = jnp.tanh(gx[:, 2 * hsz:] + r * gh[:, 2 * hsz:])
    return (1.0 - z) * n + z * h


def gru_forward(p, x):
    """x: (T=16, I=32) -> logits (10,)."""
    h = jnp.zeros((1, GRU_H), jnp.float32)

    def step(h, xt):
        return _gru_cell(p, h, xt[None]), None

    h, _ = lax.scan(step, h, x)
    return _linear(h, *p["fc"])[0]


LSTM_T, LSTM_I, LSTM_H = 16, 32, 64


def lstm_init(seed: int = 5):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {
        "wx": _init(ks[0], (LSTM_I, 4 * LSTM_H), LSTM_I),
        "wh": _init(ks[1], (LSTM_H, 4 * LSTM_H), LSTM_H),
        "b": jnp.zeros((4 * LSTM_H,), jnp.float32),
        "fc": _fc_p(ks[2], LSTM_H, 10),
    }


def _lstm_cell(p, h, c, x):
    hsz = h.shape[-1]
    g = _mm(x, p["wx"]) + _mm(h, p["wh"]) + p["b"]
    i = jax.nn.sigmoid(g[:, :hsz])
    f = jax.nn.sigmoid(g[:, hsz:2 * hsz])
    gc = jnp.tanh(g[:, 2 * hsz:3 * hsz])
    o = jax.nn.sigmoid(g[:, 3 * hsz:])
    c_new = f * c + i * gc
    return o * jnp.tanh(c_new), c_new


def lstm_forward(p, x):
    """x: (T=16, I=32) -> logits (10,)."""
    h = jnp.zeros((1, LSTM_H), jnp.float32)
    c = jnp.zeros((1, LSTM_H), jnp.float32)

    def step(hc, xt):
        h, c = _lstm_cell(p, hc[0], hc[1], xt[None])
        return (h, c), None

    (h, _), _ = lax.scan(step, (h, c), x)
    return _linear(h, *p["fc"])[0]


# ---------------------------------------------------------------------------
# Reference forwards (same math through ref.py; used by pytest to check the
# elastic-kernel-built models against an oracle path).
# ---------------------------------------------------------------------------

def cifarnet_ref(p, x):
    x = ref.maxpool2(ref.relu(ref.conv2d_same(x, p["c1"])))
    x = ref.maxpool2(ref.relu(ref.conv2d_same(x, p["c2"])))
    x = x.reshape(1, -1)
    x = ref.relu(ref.linear(x, *p["f1"]))
    return ref.linear(x, *p["f2"])[0]


def gru_ref(p, x):
    h = jnp.zeros((1, GRU_H), jnp.float32)
    for t in range(x.shape[0]):
        h = ref.gru_cell(h, x[t][None], p["wx"], p["wh"], p["b"])
    return ref.linear(h, *p["fc"])[0]


def lstm_ref(p, x):
    h = jnp.zeros((1, LSTM_H), jnp.float32)
    c = jnp.zeros((1, LSTM_H), jnp.float32)
    for t in range(x.shape[0]):
        h, c = ref.lstm_cell(h, c, x[t][None], p["wx"], p["wh"], p["b"])
    return ref.linear(h, *p["fc"])[0]


# ---------------------------------------------------------------------------
# Registry consumed by aot.py and the tests.
# ---------------------------------------------------------------------------

MODELS: Dict[str, Tuple[Tuple[int, ...], Callable, Callable]] = {
    # name: (input_shape, init_fn, forward_fn)
    "cifarnet": ((32, 32, 3), cifarnet_init, cifarnet_forward),
    "alexnet": ((64, 64, 3), alexnet_init, alexnet_forward),
    "squeezenet": ((64, 64, 3), squeezenet_init, squeezenet_forward),
    "resnet": ((32, 32, 3), resnet_init, resnet_forward),
    "gru": ((GRU_T, GRU_I), gru_init, gru_forward),
    "lstm": ((LSTM_T, LSTM_I), lstm_init, lstm_forward),
}


def build(name: str):
    """Return (input_shape, forward fn with params baked as constants)."""
    shape, init, fwd = MODELS[name]
    params = init()
    return shape, functools.partial(fwd, params)
