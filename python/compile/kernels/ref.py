"""Pure-jnp reference oracles for the elastic Pallas kernels.

These are the ground truth that every elastic configuration (any grid
slicing degree, any block/chunk size) must reproduce exactly. The paper's
source-to-source transformer claim (§6.4: elasticization preserves
computational consistency) is checked empirically against these functions
by python/tests/.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax, nn


def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Reference dense matmul: (M, K) @ (K, N) -> (M, N) in f32."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def linear(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Reference affine layer."""
    return matmul(x, w) + b


def conv2d(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Reference 2-D convolution, stride 1, VALID padding.

    x: (H, W, Cin); w: (KH, KW, Cin, Cout) -> (H-KH+1, W-KW+1, Cout).
    """
    out = lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32,
    )
    return out[0]


def conv2d_same(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Reference 2-D convolution, stride 1, SAME padding."""
    out = lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32,
    )
    return out[0]


def maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 max pool, stride 2. x: (H, W, C) with even H, W."""
    h, w, c = x.shape
    x = x.reshape(h // 2, 2, w // 2, 2, c)
    return x.max(axis=(1, 3))


def relu(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(x, 0.0)


def sigmoid(x: jnp.ndarray) -> jnp.ndarray:
    return nn.sigmoid(x)


def gru_cell(h: jnp.ndarray, x: jnp.ndarray, wx: jnp.ndarray, wh: jnp.ndarray,
             b: jnp.ndarray) -> jnp.ndarray:
    """Reference GRU cell.

    h: (B, H), x: (B, I), wx: (I, 3H), wh: (H, 3H), b: (3H,).
    Gate layout along the last axis: [reset | update | candidate].
    """
    hsz = h.shape[-1]
    gx = matmul(x, wx) + b
    gh = matmul(h, wh)
    r = sigmoid(gx[:, :hsz] + gh[:, :hsz])
    z = sigmoid(gx[:, hsz:2 * hsz] + gh[:, hsz:2 * hsz])
    n = jnp.tanh(gx[:, 2 * hsz:] + r * gh[:, 2 * hsz:])
    return (1.0 - z) * n + z * h


def lstm_cell(h: jnp.ndarray, c: jnp.ndarray, x: jnp.ndarray, wx: jnp.ndarray,
              wh: jnp.ndarray, b: jnp.ndarray):
    """Reference LSTM cell.

    h, c: (B, H), x: (B, I), wx: (I, 4H), wh: (H, 4H), b: (4H,).
    Gate layout: [input | forget | cell | output].
    """
    hsz = h.shape[-1]
    g = matmul(x, wx) + matmul(h, wh) + b
    i = sigmoid(g[:, :hsz])
    f = sigmoid(g[:, hsz:2 * hsz])
    gc = jnp.tanh(g[:, 2 * hsz:3 * hsz])
    o = sigmoid(g[:, 3 * hsz:])
    c_new = f * c + i * gc
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new
