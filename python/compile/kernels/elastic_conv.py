"""Elastic Pallas 2-D convolution kernel.

Convolution is the dominant kernel family in the MDTB models (AlexNet,
CifarNet, SqueezeNet, ResNet). The elasticity knobs mirror
``elastic_matmul``:

* **elastic grid**  — the output row range is sliced into ``2**degree``
  independent launches (paper Eq. 1 at thread-block granularity).
* **elastic block** — each program instance owns a block of ``block_rows``
  output rows x ``block_co`` output channels; shrinking either shrinks the
  per-instance VMEM footprint (the intra-SM knob of §6.1).

The kernel computes, for its (row-block, cout-block) tile:

    out[r, c, co] = sum_{kh, kw, ci} x[r+kh, c+kw, ci] * w[kh, kw, ci, co]

by unrolling the small (kh, kw) loop and contracting over ci with a dot —
i.e. the same shifted-slice + GEMM decomposition a CUDA conv kernel uses,
expressed with whole-array refs and ``pl.ds`` dynamic slices (interpret
mode; see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _conv_kernel(x_ref, w_ref, o_ref, *, block_rows: int, block_co: int,
                 out_h: int, out_w: int, kh: int, kw: int, cin: int):
    pr = pl.program_id(0)  # output-row block
    pc = pl.program_id(1)  # output-channel block
    r0 = pr * block_rows
    c0 = pc * block_co

    # Rows beyond out_h are padding rows; they exist because the caller pads
    # the output to a multiple of block_rows. Guard the store instead of the
    # loads: the input is padded accordingly so loads are in bounds.
    acc = jnp.zeros((block_rows, out_w, block_co), jnp.float32)
    for dh in range(kh):
        for dw in range(kw):
            # (block_rows, out_w, cin) input patch shifted by (dh, dw)
            xs = x_ref[pl.ds(r0 + dh, block_rows), pl.ds(dw, out_w), :]
            ws = w_ref[dh, dw, :, pl.ds(c0, block_co)]
            acc = acc + lax.dot_general(
                xs, ws, (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    o_ref[pl.ds(r0, block_rows), :, pl.ds(c0, block_co)] = acc


def conv2d_elastic(x: jnp.ndarray, w: jnp.ndarray, *, block_rows: int = 4,
                   block_co: int = 16, degree: int = 0) -> jnp.ndarray:
    """Elastic conv2d, stride 1, VALID padding.

    x: (H, W, Cin); w: (KH, KW, Cin, Cout) -> (H-KH+1, W-KW+1, Cout).
    ``degree`` slices the row-block grid into 2**degree sequential launches
    (the elastic-grid knob); ``block_rows``/``block_co`` set the per-program
    tile (the elastic-block knob). All settings agree with ``ref.conv2d``.
    """
    h, wd, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2
    out_h, out_w = h - kh + 1, wd - kw + 1
    assert out_h > 0 and out_w > 0

    row_blocks = _ceil_div(out_h, block_rows)
    co_blocks = _ceil_div(cout, block_co)
    # Pad input rows so the last row-block's loads stay in bounds, and the
    # weight cout so the last channel-block's loads stay in bounds.
    pad_h = row_blocks * block_rows + kh - 1 - h
    xp = jnp.pad(x, ((0, max(pad_h, 0)), (0, 0), (0, 0)))
    pad_co = co_blocks * block_co - cout
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, pad_co)))

    shards = 2 ** degree
    rb_per_shard = _ceil_div(row_blocks, shards)
    outs = []
    for s in range(shards):
        lo = s * rb_per_shard
        n_rb = min(rb_per_shard, max(row_blocks - lo, 0))
        if n_rb == 0:
            continue
        # Shift the shard's input window; each shard is an independent launch.
        xs = lax.dynamic_slice(
            xp, (lo * block_rows, 0, 0),
            (min(n_rb * block_rows + kh - 1, xp.shape[0] - lo * block_rows),
             wd, cin))
        xs = jnp.pad(xs, ((0, n_rb * block_rows + kh - 1 - xs.shape[0]),
                          (0, 0), (0, 0)))
        kern = functools.partial(
            _conv_kernel, block_rows=block_rows, block_co=block_co,
            out_h=out_h, out_w=out_w, kh=kh, kw=kw, cin=cin)
        out = pl.pallas_call(
            kern,
            grid=(n_rb, co_blocks),
            in_specs=[
                pl.BlockSpec(xs.shape, lambda i, j: (0, 0, 0)),
                pl.BlockSpec(wp.shape, lambda i, j: (0, 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec(
                (n_rb * block_rows, out_w, co_blocks * block_co),
                lambda i, j: (0, 0, 0)),
            out_shape=jax.ShapeDtypeStruct(
                (n_rb * block_rows, out_w, co_blocks * block_co), jnp.float32),
            interpret=True,
        )(xs, wp)
        outs.append(out)
    full = jnp.concatenate(outs, axis=0)
    return full[:out_h, :, :cout]


def conv2d_same_elastic(x: jnp.ndarray, w: jnp.ndarray, *, block_rows: int = 4,
                        block_co: int = 16, degree: int = 0) -> jnp.ndarray:
    """SAME-padded stride-1 elastic conv2d (odd kernel sizes)."""
    kh, kw = w.shape[0], w.shape[1]
    ph, pw = (kh - 1) // 2, (kw - 1) // 2
    xp = jnp.pad(x, ((ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
    return conv2d_elastic(xp, w, block_rows=block_rows, block_co=block_co,
                          degree=degree)
