"""Elastic Pallas matmul kernels (the paper's L1 compute hot-spot).

Miriam's elasticity knobs, translated from CUDA to the Pallas programming
model (see DESIGN.md §Hardware-Adaptation):

* **elastic grid**  — the number of independent launches a kernel is sliced
  into (paper Eq. 1: dichotomy slicing plan ``S(K)``). Implemented by
  :func:`matmul_sliced`, which splits the logical ``M``-axis tile range into
  ``2**degree`` shards, each a separate ``pallas_call`` — the unit the L3
  coordinator interleaves with critical kernels.
* **elastic block** — the per-"thread-block" resource footprint. On a TPU
  this is the VMEM tile shape; the persistent-thread N:1 logical→physical
  thread mapping of §6.1 becomes a grid-stride loop inside the kernel:
  :func:`matmul_persistent` launches ``num_programs`` physical program
  instances which cooperatively cover ``ceil(M/block_m)`` logical row tiles.

All variants must agree bit-for-bit-ish (allclose) with ``ref.matmul`` for
*every* knob setting — the computational-consistency requirement the paper's
source-to-source transformer (§6.4) guarantees. python/tests/test_kernels.py
sweeps the knob space with hypothesis.

All kernels use ``interpret=True``: the image's CPU PJRT cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO that both the
pytest oracle checks and the Rust runtime execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    """Zero-pad ``x`` along ``axis`` up to the next multiple of ``multiple``."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Original (inelastic) kernel: classic BlockSpec-tiled matmul.
# ---------------------------------------------------------------------------

def _tiled_kernel(x_ref, w_ref, o_ref):
    # One (bm, bn) output tile; the full K reduction happens in-kernel.
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...],
                         preferred_element_type=jnp.float32)


def matmul_tiled(x: jnp.ndarray, w: jnp.ndarray, *, bm: int = 32,
                 bn: int = 32) -> jnp.ndarray:
    """The "original GPU kernel": fixed (bm, bn) tiling over a (M/bm, N/bn)
    grid, analogous to a CUDA kernel whose launch geometry is baked in by the
    computation schedule (the situation Fig. 6 of the paper illustrates).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    xp = _pad_to(x, 0, bm)
    wp = _pad_to(w, 1, bn)
    mp, np_ = xp.shape[0], wp.shape[1]
    out = pl.pallas_call(
        _tiled_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# Elastic block: persistent-thread style kernel. ``num_programs`` physical
# instances cover all logical tiles with an N:1 grid-stride mapping.
# ---------------------------------------------------------------------------

def _persistent_kernel(x_ref, w_ref, o_ref, *, block_m: int,
                       num_programs: int, num_tiles: int):
    pid = pl.program_id(0)
    rounds = _ceil_div(num_tiles, num_programs)

    def body(r, _):
        t = pid + r * num_programs  # logical tile owned this round

        @pl.when(t < num_tiles)
        def _():
            xs = x_ref[pl.ds(t * block_m, block_m), :]
            o_ref[pl.ds(t * block_m, block_m), :] = jnp.dot(
                xs, w_ref[...], preferred_element_type=jnp.float32)

        return _

    lax.fori_loop(0, rounds, lambda r, c: (body(r, c), 0)[1], 0)


def matmul_persistent(x: jnp.ndarray, w: jnp.ndarray, *, num_programs: int = 4,
                      block_m: int = 16) -> jnp.ndarray:
    """Elastic-block matmul: the launch geometry (``num_programs``) is fully
    decoupled from the logical work decomposition (``ceil(M/block_m)`` row
    tiles), exactly the persistent-thread transformation of paper §6.1/§6.4.

    Any ``num_programs >= 1`` and ``block_m >= 1`` computes the same result.
    """
    m, k = x.shape
    _, n = w.shape
    xp = _pad_to(x, 0, block_m)
    mp = xp.shape[0]
    num_tiles = mp // block_m
    kern = functools.partial(_persistent_kernel, block_m=block_m,
                             num_programs=num_programs, num_tiles=num_tiles)
    out = pl.pallas_call(
        kern,
        grid=(num_programs,),
        in_specs=[
            pl.BlockSpec(xp.shape, lambda p: (0, 0)),
            pl.BlockSpec(w.shape, lambda p: (0, 0)),
        ],
        out_specs=pl.BlockSpec((mp, n), lambda p: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.float32),
        interpret=True,
    )(xp, w)
    return out[:m]


# ---------------------------------------------------------------------------
# Elastic grid: dichotomy slicing plan S(K) (paper Eq. 1). The kernel's tile
# range is split into 2**degree shards, each an independent launch.
# ---------------------------------------------------------------------------

def slicing_plan(num_blocks: int) -> list[int]:
    """Paper Eq. 1: S(K) = (M/2^n, M/2^{n-1}, ..., M) with n the largest
    power of two dividing M. Returns the list of admissible shard sizes."""
    n = 0
    while num_blocks % (2 ** (n + 1)) == 0:
        n += 1
    return [num_blocks // (2 ** i) for i in range(n, -1, -1)]


def matmul_shard(x: jnp.ndarray, w: jnp.ndarray, *, shard: int, degree: int,
                 bm: int = 16, bn: int = 32) -> jnp.ndarray:
    """Compute shard ``shard`` of ``2**degree`` of the row-tile range.

    The shard owns logical row tiles [shard * T/2^degree, (shard+1) * T/2^degree)
    where T = ceil(M/bm) padded up to a multiple of 2**degree. Returns the
    (rows_per_shard, N) slice of the output; concatenating all shards in
    order reconstructs the full product (tested in test_kernels.py).
    """
    m, k = x.shape
    _, n = w.shape
    shards = 2 ** degree
    xp = _pad_to(x, 0, bm)
    tiles = xp.shape[0] // bm
    tiles = _ceil_div(tiles, shards) * shards
    # Pad rows so every shard has an equal integer number of tiles.
    xp = _pad_to(xp, 0, tiles * bm)
    tiles_per_shard = tiles // shards
    row0 = shard * tiles_per_shard * bm
    rows = tiles_per_shard * bm
    xs = lax.dynamic_slice(xp, (row0, 0), (rows, k))
    return matmul_tiled(xs, w, bm=bm, bn=bn)


def matmul_sliced(x: jnp.ndarray, w: jnp.ndarray, *, degree: int,
                  bm: int = 16, bn: int = 32) -> jnp.ndarray:
    """Full elastic-grid matmul: run all ``2**degree`` shards and stitch the
    result. Semantically identical to ``ref.matmul`` for every degree."""
    m = x.shape[0]
    outs = [
        matmul_shard(x, w, shard=s, degree=degree, bm=bm, bn=bn)
        for s in range(2 ** degree)
    ]
    return jnp.concatenate(outs, axis=0)[:m]


# ---------------------------------------------------------------------------
# Fully elastic kernel: grid slicing x persistent blocks combined — the shape
# the L3 coordinator actually schedules (an "elastic kernel shard", §7).
# ---------------------------------------------------------------------------

def matmul_elastic(x: jnp.ndarray, w: jnp.ndarray, *, degree: int = 0,
                   num_programs: int = 4, block_m: int = 16) -> jnp.ndarray:
    """Elastic grid (2**degree shards) of elastic-block (persistent) matmuls."""
    m, k = x.shape
    shards = 2 ** degree
    xp = _pad_to(x, 0, block_m * shards)
    rows = xp.shape[0] // shards
    outs = [
        matmul_persistent(xp[s * rows:(s + 1) * rows], w,
                          num_programs=num_programs, block_m=block_m)
        for s in range(shards)
    ]
    return jnp.concatenate(outs, axis=0)[:m]
