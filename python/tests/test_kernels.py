"""L1 correctness: elastic Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps the elasticity knob space (shapes, slicing degrees, block
sizes, program counts) — the empirical form of the paper's §6.4 claim that
the source-to-source elastic transform preserves computational consistency
for *every* admissible configuration.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.elastic_conv import conv2d_elastic, conv2d_same_elastic
from compile.kernels.elastic_matmul import (
    matmul_elastic,
    matmul_persistent,
    matmul_shard,
    matmul_sliced,
    matmul_tiled,
    slicing_plan,
)

jax.config.update("jax_platform_name", "cpu")


def _mats(m, k, n, seed=0):
    rs = np.random.RandomState(seed)
    x = jnp.asarray(rs.randn(m, k).astype(np.float32))
    w = jnp.asarray(rs.randn(k, n).astype(np.float32))
    return x, w


def _check(out, want, rtol=1e-4, atol=1e-4):
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# matmul: fixed-point checks
# ---------------------------------------------------------------------------

class TestMatmulTiled:
    def test_square_divisible(self):
        x, w = _mats(32, 32, 32)
        _check(matmul_tiled(x, w, bm=8, bn=8), ref.matmul(x, w))

    def test_ragged_shapes(self):
        x, w = _mats(37, 19, 23)
        _check(matmul_tiled(x, w, bm=8, bn=8), ref.matmul(x, w))

    def test_single_row(self):
        x, w = _mats(1, 16, 8)
        _check(matmul_tiled(x, w, bm=4, bn=4), ref.matmul(x, w))

    def test_block_larger_than_matrix(self):
        x, w = _mats(3, 5, 4)
        _check(matmul_tiled(x, w, bm=16, bn=16), ref.matmul(x, w))

    def test_zero_input(self):
        x = jnp.zeros((8, 8), jnp.float32)
        w = jnp.ones((8, 8), jnp.float32)
        _check(matmul_tiled(x, w, bm=4, bn=4), jnp.zeros((8, 8)))


class TestMatmulPersistent:
    def test_more_programs_than_tiles(self):
        # Physical > logical: some programs own zero tiles.
        x, w = _mats(8, 8, 8)
        _check(matmul_persistent(x, w, num_programs=16, block_m=4),
               ref.matmul(x, w))

    def test_one_program_owns_everything(self):
        # Full serialization: 1 physical instance, N logical tiles (the
        # extreme persistent-thread N:1 mapping).
        x, w = _mats(40, 12, 20)
        _check(matmul_persistent(x, w, num_programs=1, block_m=4),
               ref.matmul(x, w))

    def test_uneven_tile_ownership(self):
        # tiles=7 over programs=3 -> rounds with masked tail.
        x, w = _mats(7 * 5, 9, 11)
        _check(matmul_persistent(x, w, num_programs=3, block_m=5),
               ref.matmul(x, w))


class TestSlicingPlan:
    def test_paper_eq1_power_of_two(self):
        # M=8: S(K) = (1, 2, 4, 8)
        assert slicing_plan(8) == [1, 2, 4, 8]

    def test_paper_eq1_odd(self):
        # M odd -> only the trivial plan.
        assert slicing_plan(7) == [7]

    def test_paper_eq1_mixed(self):
        assert slicing_plan(12) == [3, 6, 12]

    def test_all_entries_divide(self):
        for m in range(1, 65):
            for s in slicing_plan(m):
                assert m % s == 0


class TestMatmulSliced:
    @pytest.mark.parametrize("degree", [0, 1, 2, 3])
    def test_degrees(self, degree):
        x, w = _mats(64, 16, 24, seed=degree)
        _check(matmul_sliced(x, w, degree=degree, bm=4, bn=8),
               ref.matmul(x, w))

    def test_ragged_rows_with_slicing(self):
        x, w = _mats(50, 16, 24)
        _check(matmul_sliced(x, w, degree=2, bm=4, bn=8), ref.matmul(x, w))

    def test_shards_partition_rows(self):
        # Stitching individual shards == full product (runtime does this).
        x, w = _mats(64, 16, 24)
        parts = [
            matmul_shard(x, w, shard=s, degree=2, bm=4, bn=8)
            for s in range(4)
        ]
        _check(jnp.concatenate(parts, axis=0)[:64], ref.matmul(x, w))


# ---------------------------------------------------------------------------
# matmul: hypothesis sweeps over the elastic knob space
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=25)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 24),
    n=st.integers(1, 24),
    bm=st.integers(1, 16),
    bn=st.integers(1, 16),
)
def test_tiled_matches_ref(m, k, n, bm, bn):
    x, w = _mats(m, k, n, seed=m * 31 + k)
    _check(matmul_tiled(x, w, bm=bm, bn=bn), ref.matmul(x, w))


@settings(deadline=None, max_examples=25)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 24),
    n=st.integers(1, 24),
    num_programs=st.integers(1, 8),
    block_m=st.integers(1, 12),
)
def test_persistent_matches_ref(m, k, n, num_programs, block_m):
    x, w = _mats(m, k, n, seed=m * 17 + n)
    _check(matmul_persistent(x, w, num_programs=num_programs,
                             block_m=block_m), ref.matmul(x, w))


@settings(deadline=None, max_examples=15)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 16),
    n=st.integers(1, 16),
    degree=st.integers(0, 3),
    num_programs=st.integers(1, 4),
    block_m=st.integers(1, 8),
)
def test_fully_elastic_matches_ref(m, k, n, degree, num_programs, block_m):
    """The coordinator-facing kernel: grid slicing x persistent blocks."""
    x, w = _mats(m, k, n, seed=m + k + n + degree)
    _check(matmul_elastic(x, w, degree=degree, num_programs=num_programs,
                          block_m=block_m), ref.matmul(x, w))


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

class TestConvFixed:
    def test_basic_valid(self):
        rs = np.random.RandomState(1)
        x = jnp.asarray(rs.randn(12, 10, 3).astype(np.float32))
        w = jnp.asarray(rs.randn(3, 3, 3, 8).astype(np.float32))
        _check(conv2d_elastic(x, w, block_rows=4, block_co=4),
               ref.conv2d(x, w))

    def test_1x1_kernel(self):
        rs = np.random.RandomState(2)
        x = jnp.asarray(rs.randn(8, 8, 4).astype(np.float32))
        w = jnp.asarray(rs.randn(1, 1, 4, 6).astype(np.float32))
        _check(conv2d_elastic(x, w, block_rows=2, block_co=3),
               ref.conv2d(x, w))

    def test_5x5_kernel_same(self):
        rs = np.random.RandomState(3)
        x = jnp.asarray(rs.randn(16, 16, 3).astype(np.float32))
        w = jnp.asarray(rs.randn(5, 5, 3, 4).astype(np.float32))
        _check(conv2d_same_elastic(x, w, block_rows=4, block_co=2),
               ref.conv2d_same(x, w))

    def test_block_rows_exceed_output(self):
        rs = np.random.RandomState(4)
        x = jnp.asarray(rs.randn(6, 6, 2).astype(np.float32))
        w = jnp.asarray(rs.randn(3, 3, 2, 4).astype(np.float32))
        _check(conv2d_elastic(x, w, block_rows=16, block_co=16),
               ref.conv2d(x, w))


@settings(deadline=None, max_examples=15)
@given(
    h=st.integers(5, 18),
    wd=st.integers(5, 14),
    cin=st.integers(1, 4),
    cout=st.integers(1, 8),
    ksz=st.sampled_from([1, 3, 5]),
    block_rows=st.integers(1, 6),
    block_co=st.integers(1, 6),
    degree=st.integers(0, 2),
)
def test_conv_elastic_matches_ref(h, wd, cin, cout, ksz, block_rows,
                                  block_co, degree):
    if h < ksz or wd < ksz:
        return
    rs = np.random.RandomState(h * 7 + wd)
    x = jnp.asarray(rs.randn(h, wd, cin).astype(np.float32))
    w = jnp.asarray(rs.randn(ksz, ksz, cin, cout).astype(np.float32))
    _check(
        conv2d_elastic(x, w, block_rows=block_rows, block_co=block_co,
                       degree=degree),
        ref.conv2d(x, w), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# RNN cells (oracle self-consistency under vectorization)
# ---------------------------------------------------------------------------

def test_gru_cell_shapes():
    h = jnp.zeros((2, 8), jnp.float32)
    x = jnp.ones((2, 4), jnp.float32)
    rs = np.random.RandomState(0)
    wx = jnp.asarray(rs.randn(4, 24).astype(np.float32))
    wh = jnp.asarray(rs.randn(8, 24).astype(np.float32))
    b = jnp.zeros((24,), jnp.float32)
    out = ref.gru_cell(h, x, wx, wh, b)
    assert out.shape == (2, 8)
    assert bool(jnp.all(jnp.abs(out) <= 1.0 + 1e-6))  # tanh/sigmoid bounded


def test_lstm_cell_shapes():
    h = jnp.zeros((2, 8), jnp.float32)
    c = jnp.zeros((2, 8), jnp.float32)
    x = jnp.ones((2, 4), jnp.float32)
    rs = np.random.RandomState(0)
    wx = jnp.asarray(rs.randn(4, 32).astype(np.float32))
    wh = jnp.asarray(rs.randn(8, 32).astype(np.float32))
    b = jnp.zeros((32,), jnp.float32)
    h2, c2 = ref.lstm_cell(h, c, x, wx, wh, b)
    assert h2.shape == (2, 8) and c2.shape == (2, 8)
    assert bool(jnp.all(jnp.abs(h2) <= 1.0 + 1e-6))
