"""L2 correctness: the MDTB model zoo built on elastic kernels.

Checks (a) every model runs and emits finite logits of the right shape,
(b) models with an oracle path (cifarnet/gru/lstm through ref.py) agree
with it, and (c) determinism: the baked-params build is reproducible.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as zoo

jax.config.update("jax_platform_name", "cpu")


def _input(shape, seed=42):
    return jnp.asarray(
        np.random.RandomState(seed).randn(*shape).astype(np.float32))


@pytest.fixture(scope="module")
def outputs():
    """Run every model once (they are slow to trace); share across tests."""
    res = {}
    for name in zoo.MODELS:
        shape, fn = zoo.build(name)
        x = _input(shape)
        res[name] = (shape, np.asarray(jax.jit(fn)(x)))
    return res


@pytest.mark.parametrize("name", list(zoo.MODELS))
def test_model_shape_and_finite(outputs, name):
    _, y = outputs[name]
    assert y.shape == (10,)
    assert np.all(np.isfinite(y))


@pytest.mark.parametrize("name", list(zoo.MODELS))
def test_model_not_degenerate(outputs, name):
    # Logits must not collapse to a constant (catches zeroed weights, e.g.
    # an elided-constant regression in the AOT path).
    _, y = outputs[name]
    assert np.std(y) > 1e-4


def test_cifarnet_matches_ref_path():
    p = zoo.cifarnet_init()
    x = _input((32, 32, 3))
    got = jax.jit(lambda x: zoo.cifarnet_forward(p, x))(x)
    want = zoo.cifarnet_ref(p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_gru_matches_ref_path():
    p = zoo.gru_init()
    x = _input((zoo.GRU_T, zoo.GRU_I))
    got = jax.jit(lambda x: zoo.gru_forward(p, x))(x)
    want = zoo.gru_ref(p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_lstm_matches_ref_path():
    p = zoo.lstm_init()
    x = _input((zoo.LSTM_T, zoo.LSTM_I))
    got = jax.jit(lambda x: zoo.lstm_forward(p, x))(x)
    want = zoo.lstm_ref(p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_build_deterministic():
    # Same seed -> same params -> same logits. The manifest goldens rely on
    # this: rust executes the artifact and compares against these numbers.
    shape, fn1 = zoo.build("gru")
    _, fn2 = zoo.build("gru")
    x = _input(shape)
    np.testing.assert_array_equal(np.asarray(jax.jit(fn1)(x)),
                                  np.asarray(jax.jit(fn2)(x)))


def test_registry_complete():
    # The six MDTB models of paper Table 2 / §8.1.2.
    assert set(zoo.MODELS) == {
        "alexnet", "squeezenet", "gru", "lstm", "resnet", "cifarnet"}
