"""AOT artifact integrity: manifest structure + golden numerics.

These tests only run when `make artifacts` has produced artifacts/ — they
are the python half of the cross-language contract with
rust/src/runtime/artifacts.rs (which performs the same golden checks after
the HLO-text round trip through the PJRT CPU client).
"""

import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as zoo

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first")


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_lists_all_models(manifest):
    names = {e["name"] for e in manifest["artifacts"] if e["kind"] == "model"}
    assert names == set(zoo.MODELS)


def test_artifact_files_exist(manifest):
    for e in manifest["artifacts"]:
        if "file" in e:
            assert os.path.exists(os.path.join(ART, e["file"])), e["file"]


def test_no_elided_constants(manifest):
    # Regression guard: the default HLO printer elides large constants as
    # `constant({...})`, which silently destroys the baked weights.
    for e in manifest["artifacts"]:
        if "file" not in e:
            continue
        with open(os.path.join(ART, e["file"])) as f:
            text = f.read()
        assert "constant({...})" not in text, e["file"]


def test_goldens_match_fresh_forward(manifest):
    # Re-run each model in-process on the golden input; the manifest numbers
    # were produced by the lowered/AOT'd path — they must agree exactly.
    for e in manifest["artifacts"]:
        if e["kind"] != "model":
            continue
        shape, fn = zoo.build(e["name"])
        x = np.random.RandomState(e["golden"]["input_seed"]).randn(*shape)
        x = x.astype(np.float32)
        assert hashlib.sha256(x.tobytes()).hexdigest()[:16] == \
            e["golden"]["input_sha"]
        y = np.asarray(jax.jit(fn)(jnp.asarray(x)))
        np.testing.assert_allclose(
            y, np.asarray(e["golden"]["output"], np.float32),
            rtol=1e-4, atol=1e-5)


def test_matmul_golden_consistency(manifest):
    gold = next(e for e in manifest["artifacts"] if e["kind"] == "golden")
    x = np.random.RandomState(gold["x_seed"]).randn(
        gold["m"], gold["k"]).astype(np.float32)
    w = np.random.RandomState(gold["w_seed"]).randn(
        gold["k"], gold["n"]).astype(np.float32)
    full = (x @ w).astype(np.float32)
    assert hashlib.sha256(full.tobytes()).hexdigest()[:16] == \
        gold["output_sha"]
    np.testing.assert_allclose(full.ravel()[:8],
                               np.asarray(gold["output_first8"]), rtol=1e-5)


def test_shard_family_covers_dichotomy(manifest):
    # Paper Eq. 1: shard sizes must be M / 2^d for d = 0..3.
    rows = sorted(
        e["rows"] for e in manifest["artifacts"]
        if e["kind"] == "matmul_shard")
    assert rows == [8, 16, 32, 64]
