#!/usr/bin/env python3
"""Bench regression gate (ISSUE 3 satellite).

Compares a freshly produced BENCH_engine.json against a committed baseline
and fails on a >20% events/sec regression of the incremental engine path.

Usage:
    bench_gate.py MEASURED_JSON BASELINE_JSON [--tolerance 0.20]

Bootstrap behaviour: if the baseline is missing, or carries
``"bootstrap": true``, or has no numeric ``events_per_sec_incremental``,
the gate prints the measured numbers and exits 0.

Arming the gate — compare like-for-like: the baseline MUST be recorded
under the same conditions the gate measures, i.e. promote the
``BENCH_engine.json`` *artifact from a healthy CI run* (which is a
``--smoke`` run on a CI runner) to ``benchmarks/BENCH_engine.baseline.json``.
Do NOT commit a full run from a fast dev machine as the baseline: CI
smoke throughput on a shared runner is far below a quiet workstation's
full-run numbers and the gate would fail on every push. Full-run numbers
belong in EXPERIMENTS.md §Perf (and cross-machine comparisons should use
the machine-independent ``speedup`` / ``coordinator.improvement``
ratios), not in this baseline. The 20% tolerance is sized for CI-runner
noise around a CI-recorded baseline.
"""

import json
import sys


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 2
    measured_path, baseline_path = argv[1], argv[2]
    tolerance = 0.20
    if "--tolerance" in argv:
        tolerance = float(argv[argv.index("--tolerance") + 1])

    with open(measured_path) as f:
        measured = json.load(f)
    m_inc = measured.get("events_per_sec_incremental")
    m_ref = measured.get("events_per_sec_reference")
    m_speedup = measured.get("speedup")
    coord = measured.get("coordinator", {})
    print(f"measured: incremental {m_inc} ev/s, reference {m_ref} ev/s, "
          f"speedup {m_speedup}x, coordinator improvement "
          f"{coord.get('improvement')}")

    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"gate: no baseline at {baseline_path} — bootstrap pass. "
              f"Promote a CI-run BENCH_engine.json artifact there to arm "
              f"the gate (like-for-like conditions; see module docstring).")
        return 0
    b_inc = baseline.get("events_per_sec_incremental")
    if baseline.get("bootstrap") or not isinstance(b_inc, (int, float)):
        print("gate: baseline is a bootstrap placeholder — pass. "
              "Promote a CI-run BENCH_engine.json artifact to arm the gate "
              "(like-for-like conditions; see module docstring).")
        return 0
    if baseline.get("smoke") is not None and baseline.get("smoke") != \
            measured.get("smoke"):
        print("gate: baseline and measured runs used different modes "
              f"(baseline smoke={baseline.get('smoke')}, measured "
              f"smoke={measured.get('smoke')}) — not comparable, pass. "
              "Re-record the baseline under the gate's conditions.")
        return 0

    if not isinstance(m_inc, (int, float)) or m_inc <= 0:
        print("gate: FAIL — measured JSON has no events_per_sec_incremental")
        return 1
    floor = (1.0 - tolerance) * b_inc
    if m_inc < floor:
        print(f"gate: FAIL — incremental {m_inc:.0f} ev/s is below "
              f"{floor:.0f} (baseline {b_inc:.0f} - {tolerance:.0%})")
        return 1
    print(f"gate: OK — incremental {m_inc:.0f} ev/s vs baseline "
          f"{b_inc:.0f} (floor {floor:.0f})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
