#!/usr/bin/env python3
"""Bench regression gate (ISSUE 3 satellite; fleet: ISSUE 5; resilience:
ISSUE 6).

Compares a freshly produced bench JSON against a committed baseline.
The mode is dispatched on the measured document's ``"bench"`` key:

* engine (default / ``BENCH_engine.json``): fails on a >20% events/sec
  regression of the incremental engine path — a host-timing metric, so
  the tolerance absorbs CI-runner noise.
* ``"bench": "fleet"`` (``BENCH_fleet.json``): fails when any baseline
  cell is missing from the measured report (coverage regression), when
  served counts drift by more than 2%, or when a cell's critical p99
  drifts by more than 5% against the baseline. ``--tolerance`` overrides
  both fleet thresholds. Fleet reports carry **no host timing**
  (byte-deterministic per seed), so the small tolerances only absorb
  libm last-ulp differences across hosts; real drift is a semantic
  change and should be an intentional baseline refresh.
* ``"bench": "resilience"`` (``BENCH_resilience.json``): same contract
  as fleet mode over the ``comparisons`` rows, keyed
  ``(scenario, storm, router)`` — coverage regression, 2% served and
  requeue drift, 5% critical-p99 drift — plus one unconditional
  invariant: **no cell may report a lost request** (every storm preset
  heals, so a nonzero ``lost`` is a chaos-layer bug regardless of what
  the baseline says).
* ``"bench": "scale"`` (``BENCH_scale.json``): fleet-style contract
  over the ``cells`` rows keyed by tenant count — coverage regression,
  2% served drift, 5% worst-tenant-p99 drift — plus one unconditional
  invariant: **per-tenant latency-accounting bytes stay constant**
  (``bytes_per_tenant`` ≤ 512 in every cell; the streaming sketch is
  the whole point of the scale path, so a cell that grew past that is
  a memory regression regardless of what the baseline says).
* ``"bench": "faults"`` (``BENCH_faults.json``): resilience-style
  contract over the ``comparisons`` rows keyed
  ``(scenario, faults, router)`` — coverage regression, 2% drift on
  served / retries / cancelled counts, 5% critical-p99 drift — plus
  unconditional invariants that hold even in bootstrap: extended
  conservation on every row (``offered == admitted + shed`` and
  ``admitted == served + lost + cancelled``), ``lost == 0`` (pure
  fault injection keeps every device live), ``critical_cancelled ==
  0`` (deadline-aware cancellation never touches critical requests),
  and ``hedge_wins <= hedges`` (a hedged request wins at most once).
* ``"bench": "isolation"`` (``BENCH_isolation.json``): fleet-style
  contract over the ``comparisons`` rows keyed
  ``(scenario, scheduler)`` — coverage regression, 2% throughput
  drift, 5% critical-p99 drift — plus one unconditional invariant:
  **isolation critical p99 ≤ miriam critical p99 × 1.05** on every
  row (a partition that dedicates SMs to criticals and still serves
  them materially slower than whole-device sharing means the SM-mask
  placement path is broken, regardless of what the baseline says).
* ``"bench": "gen"`` (``BENCH_gen.json``): fleet-style contract over
  the ``cells`` rows keyed ``(scenario, kind, policy)`` — coverage
  regression, 2% tokens/sec drift, 5% critical-TTFT-p99 drift — plus
  unconditional invariants that hold even in bootstrap: **token
  conservation** (``tokens == drawn_tokens``: every admitted request
  emits exactly its drawn output length, evictions included),
  **criticals are never evicted** (``critical_evictions == 0``),
  **TTFT never exceeds end-to-end latency** (``ttft_violations ==
  0``), arrival accounting (``offered == admitted + shed``), and
  **recompute equals the evicted prefix** (``recompute_tokens ==
  evicted_prefix_tokens``: evict-and-recompute re-issues exactly what
  it dropped, no more, no less).

Usage:
    bench_gate.py MEASURED_JSON BASELINE_JSON [--tolerance 0.20]

Bootstrap behaviour (both modes): if the baseline is missing, or carries
``"bootstrap": true``, or has no comparable numbers, the gate prints the
measured numbers and exits 0.

Arming the gate — compare like-for-like: the baseline MUST be recorded
under the same conditions the gate measures, i.e. promote the
``BENCH_engine.json`` *artifact from a healthy CI run* (which is a
``--smoke`` run on a CI runner) to ``benchmarks/BENCH_engine.baseline.json``.
Do NOT commit a full run from a fast dev machine as the baseline: CI
smoke throughput on a shared runner is far below a quiet workstation's
full-run numbers and the gate would fail on every push. Full-run numbers
belong in EXPERIMENTS.md §Perf (and cross-machine comparisons should use
the machine-independent ``speedup`` / ``coordinator.improvement``
ratios), not in this baseline. The 20% tolerance is sized for CI-runner
noise around a CI-recorded baseline.
"""

import json
import sys


def fleet_gate(measured, baseline_path, tolerance=None):
    """Deterministic-report gate for BENCH_fleet.json documents.

    ``tolerance``, when given (the CLI's ``--tolerance``), overrides both
    the served-count (default 2%) and critical-p99 (default 5%) drift
    thresholds.
    """
    served_tol = tolerance if tolerance is not None else 0.02
    p99_tol = tolerance if tolerance is not None else 0.05
    cells = measured.get("cells", [])
    served = sum(c.get("served", 0) for c in cells)
    print(f"measured: {len(cells)} fleet cell(s), {served} served total")
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"gate: no baseline at {baseline_path} — bootstrap pass. "
              f"Promote a CI-run BENCH_fleet.json artifact there to arm "
              f"the gate (same --smoke conditions).")
        return 0
    if baseline.get("bootstrap") or not baseline.get("cells"):
        print("gate: fleet baseline is a bootstrap placeholder — pass. "
              "Promote a CI-run BENCH_fleet.json artifact to arm the gate.")
        return 0
    base_cells = {(c.get("scenario"), c.get("router")): c
                  for c in baseline.get("cells", [])}
    measured_keys = {(c.get("scenario"), c.get("router")) for c in cells}
    failures = []
    # A baseline cell with no measured counterpart is a coverage
    # regression (a router or scenario silently dropped from the bench),
    # not a pass.
    for key in sorted(k for k in base_cells if k not in measured_keys):
        failures.append(f"{key}: in baseline but missing from measured "
                        f"report (coverage regression)")
    for c in cells:
        key = (c.get("scenario"), c.get("router"))
        b = base_cells.get(key)
        if b is None:
            continue  # new cell: no baseline yet, nothing to regress
        bs, ms = b.get("served", 0), c.get("served", 0)
        if bs and abs(ms - bs) > served_tol * bs:
            failures.append(f"{key}: served {ms} vs baseline {bs}")
        bp, mp = b.get("crit_p99_us"), c.get("crit_p99_us")
        if (isinstance(bp, (int, float)) and isinstance(mp, (int, float))
                and bp > 0 and abs(mp - bp) > p99_tol * bp):
            failures.append(f"{key}: crit_p99_us {mp:.1f} vs "
                            f"baseline {bp:.1f}")
    if failures:
        print("gate: FAIL — fleet report drifted from baseline "
              "(intentional change? refresh benchmarks/"
              "BENCH_fleet.baseline.json from a healthy CI artifact):")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print(f"gate: OK — {len(cells)} fleet cell(s) within tolerance of "
          f"baseline")
    return 0


def resilience_gate(measured, baseline_path, tolerance=None):
    """Deterministic-report gate for BENCH_resilience.json documents.

    Works over the ``comparisons`` rows (one per grid cell) keyed by
    ``(scenario, storm, router)``. Like the fleet gate, but requeue
    counts are held to the served tolerance too, and a nonzero ``lost``
    fails unconditionally.
    """
    served_tol = tolerance if tolerance is not None else 0.02
    p99_tol = tolerance if tolerance is not None else 0.05
    rows = measured.get("comparisons", [])
    lost = sum(r.get("lost", 0) for r in rows)
    print(f"measured: {len(rows)} resilience cell(s), "
          f"{sum(r.get('served', 0) for r in rows)} served total, "
          f"{sum(r.get('requeues', 0) for r in rows)} requeues, "
          f"{lost} lost")
    failures = []
    if lost:
        failures.append(f"{lost} request(s) lost — every storm preset "
                        f"heals, so lost must be 0 in every cell")
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        baseline = None
        if not failures:
            print(f"gate: no baseline at {baseline_path} — bootstrap "
                  f"pass. Promote a CI-run BENCH_resilience.json artifact "
                  f"there to arm the gate (same --smoke conditions).")
            return 0
    if baseline is not None and (baseline.get("bootstrap")
                                 or not baseline.get("comparisons")):
        baseline = None
        if not failures:
            print("gate: resilience baseline is a bootstrap placeholder "
                  "— pass. Promote a CI-run BENCH_resilience.json "
                  "artifact to arm the gate.")
            return 0
    if baseline is not None:
        key = lambda r: (r.get("scenario"), r.get("storm"), r.get("router"))
        base_rows = {key(r): r for r in baseline.get("comparisons", [])}
        measured_keys = {key(r) for r in rows}
        for k in sorted(k for k in base_rows if k not in measured_keys):
            failures.append(f"{k}: in baseline but missing from measured "
                            f"report (coverage regression)")
        for r in rows:
            b = base_rows.get(key(r))
            if b is None:
                continue  # new cell: no baseline yet, nothing to regress
            for field, tol in (("served", served_tol),
                               ("requeues", served_tol)):
                bv, mv = b.get(field, 0), r.get(field, 0)
                if bv and abs(mv - bv) > tol * bv:
                    failures.append(f"{key(r)}: {field} {mv} vs "
                                    f"baseline {bv}")
            bp, mp = b.get("crit_p99_us"), r.get("crit_p99_us")
            if (isinstance(bp, (int, float)) and isinstance(mp, (int, float))
                    and bp > 0 and abs(mp - bp) > p99_tol * bp):
                failures.append(f"{key(r)}: crit_p99_us {mp:.1f} vs "
                                f"baseline {bp:.1f}")
    if failures:
        print("gate: FAIL — resilience report violated an invariant or "
              "drifted from baseline (intentional change? refresh "
              "benchmarks/BENCH_resilience.baseline.json from a healthy "
              "CI artifact):")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print(f"gate: OK — {len(rows)} resilience cell(s) within tolerance "
          f"of baseline, 0 lost")
    return 0


def scale_gate(measured, baseline_path, tolerance=None):
    """Deterministic-report gate for BENCH_scale.json documents.

    Cells are keyed by tenant count. Like the fleet gate (2% served
    drift, 5% worst-tenant-p99 drift, coverage regression), plus one
    unconditional invariant: per-tenant accounting bytes must stay
    constant (≤ 512) in every cell, baseline or not.
    """
    served_tol = tolerance if tolerance is not None else 0.02
    p99_tol = tolerance if tolerance is not None else 0.05
    cells = measured.get("cells", [])
    served = sum(c.get("served", 0) for c in cells)
    print(f"measured: {len(cells)} scale cell(s), {served} served total, "
          f"tenant counts {[c.get('tenants') for c in cells]}")
    failures = []
    for c in cells:
        bpt = c.get("bytes_per_tenant")
        if not isinstance(bpt, (int, float)) or not 0 < bpt <= 512:
            failures.append(
                f"{c.get('tenants')} tenants: bytes_per_tenant {bpt} "
                f"outside (0, 512] — constant-memory contract broken")
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        baseline = None
        if not failures:
            print(f"gate: no baseline at {baseline_path} — bootstrap "
                  f"pass. Promote a CI-run BENCH_scale.json artifact "
                  f"there to arm the gate (same --smoke conditions).")
            return 0
    if baseline is not None and (baseline.get("bootstrap")
                                 or not baseline.get("cells")):
        baseline = None
        if not failures:
            print("gate: scale baseline is a bootstrap placeholder — "
                  "pass. Promote a CI-run BENCH_scale.json artifact to "
                  "arm the gate.")
            return 0
    if baseline is not None:
        base_cells = {c.get("tenants"): c for c in baseline.get("cells", [])}
        measured_keys = {c.get("tenants") for c in cells}
        for k in sorted(k for k in base_cells if k not in measured_keys):
            failures.append(f"{k} tenants: in baseline but missing from "
                            f"measured report (coverage regression)")
        for c in cells:
            b = base_cells.get(c.get("tenants"))
            if b is None:
                continue  # new cell: no baseline yet, nothing to regress
            bs, ms = b.get("served", 0), c.get("served", 0)
            if bs and abs(ms - bs) > served_tol * bs:
                failures.append(f"{c.get('tenants')} tenants: served "
                                f"{ms} vs baseline {bs}")
            bp = b.get("worst_tenant_p99_us")
            mp = c.get("worst_tenant_p99_us")
            if (isinstance(bp, (int, float)) and isinstance(mp, (int, float))
                    and bp > 0 and abs(mp - bp) > p99_tol * bp):
                failures.append(f"{c.get('tenants')} tenants: "
                                f"worst_tenant_p99_us {mp:.1f} vs "
                                f"baseline {bp:.1f}")
    if failures:
        print("gate: FAIL — scale report violated an invariant or "
              "drifted from baseline (intentional change? refresh "
              "benchmarks/BENCH_scale.baseline.json from a healthy CI "
              "artifact):")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print(f"gate: OK — {len(cells)} scale cell(s) within tolerance of "
          f"baseline, constant per-tenant memory")
    return 0


def faults_gate(measured, baseline_path, tolerance=None):
    """Deterministic-report gate for BENCH_faults.json documents.

    Works over the ``comparisons`` rows (one per grid cell) keyed by
    ``(scenario, faults, router)``. The recovery-layer invariants —
    extended conservation, nothing lost, critical never cancelled,
    hedge winners counted at most once — are checked unconditionally
    on every row, baseline or not; drift checks (served / retries /
    cancelled within the served tolerance, critical p99 within the p99
    tolerance) arm once a real baseline is promoted.
    """
    served_tol = tolerance if tolerance is not None else 0.02
    p99_tol = tolerance if tolerance is not None else 0.05
    rows = measured.get("comparisons", [])
    print(f"measured: {len(rows)} faults cell(s), "
          f"{sum(r.get('served', 0) for r in rows)} served total, "
          f"{sum(r.get('retries', 0) for r in rows)} retries, "
          f"{sum(r.get('hedges', 0) for r in rows)} hedges, "
          f"{sum(r.get('cancelled', 0) for r in rows)} cancelled")
    key = lambda r: (r.get("scenario"), r.get("faults"), r.get("router"))
    failures = []
    for r in rows:
        offered = r.get("offered", 0)
        admitted = r.get("admitted", 0)
        shed = r.get("shed", 0)
        served = r.get("served", 0)
        lost = r.get("lost", 0)
        cancelled = r.get("cancelled", 0)
        if offered != admitted + shed:
            failures.append(f"{key(r)}: offered {offered} != admitted "
                            f"{admitted} + shed {shed} (conservation)")
        if admitted != served + lost + cancelled:
            failures.append(f"{key(r)}: admitted {admitted} != served "
                            f"{served} + lost {lost} + cancelled "
                            f"{cancelled} (extended conservation)")
        if lost:
            failures.append(f"{key(r)}: {lost} request(s) lost — pure "
                            f"fault injection keeps every device live, "
                            f"so lost must be 0")
        if r.get("critical_cancelled", 0):
            failures.append(f"{key(r)}: {r.get('critical_cancelled')} "
                            f"critical request(s) cancelled — "
                            f"deadline-aware cancellation must never "
                            f"touch critical requests")
        if r.get("hedge_wins", 0) > r.get("hedges", 0):
            failures.append(f"{key(r)}: hedge_wins "
                            f"{r.get('hedge_wins')} > hedges "
                            f"{r.get('hedges')} — a hedged request can "
                            f"win at most once")
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        baseline = None
        if not failures:
            print(f"gate: no baseline at {baseline_path} — bootstrap "
                  f"pass (invariants held). Promote a CI-run "
                  f"BENCH_faults.json artifact there to arm the gate "
                  f"(same --smoke conditions).")
            return 0
    if baseline is not None and (baseline.get("bootstrap")
                                 or not baseline.get("comparisons")):
        baseline = None
        if not failures:
            print("gate: faults baseline is a bootstrap placeholder — "
                  "pass (invariants held). Promote a CI-run "
                  "BENCH_faults.json artifact to arm the gate.")
            return 0
    if baseline is not None:
        base_rows = {key(r): r for r in baseline.get("comparisons", [])}
        measured_keys = {key(r) for r in rows}
        for k in sorted(k for k in base_rows if k not in measured_keys):
            failures.append(f"{k}: in baseline but missing from measured "
                            f"report (coverage regression)")
        for r in rows:
            b = base_rows.get(key(r))
            if b is None:
                continue  # new cell: no baseline yet, nothing to regress
            for field in ("served", "retries", "cancelled"):
                bv, mv = b.get(field, 0), r.get(field, 0)
                if bv and abs(mv - bv) > served_tol * bv:
                    failures.append(f"{key(r)}: {field} {mv} vs "
                                    f"baseline {bv}")
            bp, mp = b.get("crit_p99_us"), r.get("crit_p99_us")
            if (isinstance(bp, (int, float)) and isinstance(mp, (int, float))
                    and bp > 0 and abs(mp - bp) > p99_tol * bp):
                failures.append(f"{key(r)}: crit_p99_us {mp:.1f} vs "
                                f"baseline {bp:.1f}")
    if failures:
        print("gate: FAIL — faults report violated a recovery-layer "
              "invariant or drifted from baseline (intentional change? "
              "refresh benchmarks/BENCH_faults.baseline.json from a "
              "healthy CI artifact; invariant failures are bugs, not "
              "baseline drift):")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print(f"gate: OK — {len(rows)} faults cell(s) conserve requests, "
          f"never cancel criticals, and sit within tolerance of baseline")
    return 0


def isolation_gate(measured, baseline_path, tolerance=None):
    """Deterministic-report gate for BENCH_isolation.json documents.

    Works over the ``comparisons`` rows (one per (scenario, isolation
    scheduler) aggregate) keyed ``(scenario, scheduler)``. The
    partitioning invariant — isolation critical p99 at or below miriam
    critical p99 × 1.05 — is checked unconditionally on every row,
    baseline or not; drift checks (throughput within the served
    tolerance, critical p99 within the p99 tolerance) arm once a real
    baseline is promoted.
    """
    served_tol = tolerance if tolerance is not None else 0.02
    p99_tol = tolerance if tolerance is not None else 0.05
    headroom = measured.get("crit_p99_tolerance", 1.05)
    rows = measured.get("comparisons", [])
    print(f"measured: {len(rows)} isolation cell(s) on "
          f"{measured.get('platform')}, schedulers "
          f"{[s for s in measured.get('schedulers', []) if str(s).startswith('isolation')]}")
    key = lambda r: (r.get("scenario"), r.get("scheduler"))
    failures = []
    for r in rows:
        mp, ep = r.get("crit_p99_us"), r.get("miriam_crit_p99_us")
        if (isinstance(mp, (int, float)) and isinstance(ep, (int, float))
                and ep > 0 and mp > ep * headroom):
            failures.append(
                f"{key(r)}: isolation crit_p99_us {mp:.1f} > miriam "
                f"{ep:.1f} x {headroom} — a dedicated critical partition "
                f"must not be materially slower than sharing")
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        baseline = None
        if not failures:
            print(f"gate: no baseline at {baseline_path} — bootstrap "
                  f"pass (invariant held). Promote a CI-run "
                  f"BENCH_isolation.json artifact there to arm the gate "
                  f"(same --smoke conditions).")
            return 0
    if baseline is not None and (baseline.get("bootstrap")
                                 or not baseline.get("comparisons")):
        baseline = None
        if not failures:
            print("gate: isolation baseline is a bootstrap placeholder — "
                  "pass (invariant held). Promote a CI-run "
                  "BENCH_isolation.json artifact to arm the gate.")
            return 0
    if baseline is not None:
        base_rows = {key(r): r for r in baseline.get("comparisons", [])}
        measured_keys = {key(r) for r in rows}
        for k in sorted(k for k in base_rows if k not in measured_keys):
            failures.append(f"{k}: in baseline but missing from measured "
                            f"report (coverage regression)")
        for r in rows:
            b = base_rows.get(key(r))
            if b is None:
                continue  # new cell: no baseline yet, nothing to regress
            bt, mt = b.get("throughput_rps"), r.get("throughput_rps")
            if (isinstance(bt, (int, float)) and isinstance(mt, (int, float))
                    and bt > 0 and abs(mt - bt) > served_tol * bt):
                failures.append(f"{key(r)}: throughput_rps {mt:.1f} vs "
                                f"baseline {bt:.1f}")
            bp, mp = b.get("crit_p99_us"), r.get("crit_p99_us")
            if (isinstance(bp, (int, float)) and isinstance(mp, (int, float))
                    and bp > 0 and abs(mp - bp) > p99_tol * bp):
                failures.append(f"{key(r)}: crit_p99_us {mp:.1f} vs "
                                f"baseline {bp:.1f}")
    if failures:
        print("gate: FAIL — isolation report violated the partitioning "
              "invariant or drifted from baseline (intentional change? "
              "refresh benchmarks/BENCH_isolation.baseline.json from a "
              "healthy CI artifact; invariant failures are bugs, not "
              "baseline drift):")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print(f"gate: OK — {len(rows)} isolation cell(s) keep critical p99 "
          f"within {headroom}x of miriam and sit within tolerance of "
          f"baseline")
    return 0


def gen_gate(measured, baseline_path, tolerance=None):
    """Deterministic-report gate for BENCH_gen.json documents.

    Works over the ``cells`` rows keyed ``(scenario, kind, policy)``.
    The generation-ledger invariants — token conservation, criticals
    never evicted, TTFT bounded by end-to-end latency, arrival
    accounting, recompute matching the evicted prefix — are checked
    unconditionally on every cell, baseline or not; drift checks
    (tokens/sec within the served tolerance, critical TTFT p99 within
    the p99 tolerance) arm once a real baseline is promoted.
    """
    served_tol = tolerance if tolerance is not None else 0.02
    p99_tol = tolerance if tolerance is not None else 0.05
    cells = measured.get("cells", [])
    print(f"measured: {len(cells)} gen cell(s) on "
          f"{measured.get('platform')}, "
          f"{sum(c.get('tokens', 0) for c in cells)} tokens total, "
          f"{sum(c.get('evictions', 0) for c in cells)} evictions")
    key = lambda c: (c.get("scenario"), c.get("kind"), c.get("policy"))
    failures = []
    for c in cells:
        tokens = c.get("tokens", 0)
        drawn = c.get("drawn_tokens", 0)
        if tokens != drawn:
            failures.append(f"{key(c)}: tokens {tokens} != drawn "
                            f"{drawn} — an admitted request must emit "
                            f"exactly its drawn output length")
        if c.get("critical_evictions", 0):
            failures.append(f"{key(c)}: {c.get('critical_evictions')} "
                            f"critical KV eviction(s) — memory pressure "
                            f"must never evict a critical request")
        if c.get("ttft_violations", 0):
            failures.append(f"{key(c)}: {c.get('ttft_violations')} "
                            f"TTFT > end-to-end latency violation(s)")
        offered = c.get("offered", 0)
        admitted = c.get("admitted", 0)
        shed = c.get("shed", 0)
        if offered != admitted + shed:
            failures.append(f"{key(c)}: offered {offered} != admitted "
                            f"{admitted} + shed {shed} (conservation)")
        if c.get("recompute_tokens", 0) != c.get("evicted_prefix_tokens", 0):
            failures.append(f"{key(c)}: recompute_tokens "
                            f"{c.get('recompute_tokens')} != "
                            f"evicted_prefix_tokens "
                            f"{c.get('evicted_prefix_tokens')} — "
                            f"evict-and-recompute must re-issue exactly "
                            f"the dropped prefix")
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        baseline = None
        if not failures:
            print(f"gate: no baseline at {baseline_path} — bootstrap "
                  f"pass (invariants held). Promote a CI-run "
                  f"BENCH_gen.json artifact there to arm the gate "
                  f"(same --smoke conditions).")
            return 0
    if baseline is not None and (baseline.get("bootstrap")
                                 or not baseline.get("cells")):
        baseline = None
        if not failures:
            print("gate: gen baseline is a bootstrap placeholder — "
                  "pass (invariants held). Promote a CI-run "
                  "BENCH_gen.json artifact to arm the gate.")
            return 0
    if baseline is not None:
        base_cells = {key(c): c for c in baseline.get("cells", [])}
        measured_keys = {key(c) for c in cells}
        for k in sorted(k for k in base_cells if k not in measured_keys):
            failures.append(f"{k}: in baseline but missing from measured "
                            f"report (coverage regression)")
        for c in cells:
            b = base_cells.get(key(c))
            if b is None:
                continue  # new cell: no baseline yet, nothing to regress
            bt, mt = b.get("tokens_per_sec"), c.get("tokens_per_sec")
            if (isinstance(bt, (int, float)) and isinstance(mt, (int, float))
                    and bt > 0 and abs(mt - bt) > served_tol * bt):
                failures.append(f"{key(c)}: tokens_per_sec {mt:.1f} vs "
                                f"baseline {bt:.1f}")
            bp, mp = b.get("crit_ttft_p99_us"), c.get("crit_ttft_p99_us")
            if (isinstance(bp, (int, float)) and isinstance(mp, (int, float))
                    and bp > 0 and abs(mp - bp) > p99_tol * bp):
                failures.append(f"{key(c)}: crit_ttft_p99_us {mp:.1f} vs "
                                f"baseline {bp:.1f}")
    if failures:
        print("gate: FAIL — gen report violated a generation-ledger "
              "invariant or drifted from baseline (intentional change? "
              "refresh benchmarks/BENCH_gen.baseline.json from a healthy "
              "CI artifact; invariant failures are bugs, not baseline "
              "drift):")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print(f"gate: OK — {len(cells)} gen cell(s) conserve tokens, never "
          f"evict criticals, and sit within tolerance of baseline")
    return 0


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 2
    measured_path, baseline_path = argv[1], argv[2]
    tolerance = 0.20
    if "--tolerance" in argv:
        tolerance = float(argv[argv.index("--tolerance") + 1])

    with open(measured_path) as f:
        measured = json.load(f)
    if measured.get("bench") == "fleet":
        return fleet_gate(measured, baseline_path,
                          tolerance if "--tolerance" in argv else None)
    if measured.get("bench") == "resilience":
        return resilience_gate(measured, baseline_path,
                               tolerance if "--tolerance" in argv else None)
    if measured.get("bench") == "scale":
        return scale_gate(measured, baseline_path,
                          tolerance if "--tolerance" in argv else None)
    if measured.get("bench") == "faults":
        return faults_gate(measured, baseline_path,
                           tolerance if "--tolerance" in argv else None)
    if measured.get("bench") == "isolation":
        return isolation_gate(measured, baseline_path,
                              tolerance if "--tolerance" in argv else None)
    if measured.get("bench") == "gen":
        return gen_gate(measured, baseline_path,
                        tolerance if "--tolerance" in argv else None)
    m_inc = measured.get("events_per_sec_incremental")
    m_ref = measured.get("events_per_sec_reference")
    m_speedup = measured.get("speedup")
    coord = measured.get("coordinator", {})
    print(f"measured: incremental {m_inc} ev/s, reference {m_ref} ev/s, "
          f"speedup {m_speedup}x, coordinator improvement "
          f"{coord.get('improvement')}")

    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"gate: no baseline at {baseline_path} — bootstrap pass. "
              f"Promote a CI-run BENCH_engine.json artifact there to arm "
              f"the gate (like-for-like conditions; see module docstring).")
        return 0
    b_inc = baseline.get("events_per_sec_incremental")
    if baseline.get("bootstrap") or not isinstance(b_inc, (int, float)):
        print("gate: baseline is a bootstrap placeholder — pass. "
              "Promote a CI-run BENCH_engine.json artifact to arm the gate "
              "(like-for-like conditions; see module docstring).")
        return 0
    if baseline.get("smoke") is not None and baseline.get("smoke") != \
            measured.get("smoke"):
        print("gate: baseline and measured runs used different modes "
              f"(baseline smoke={baseline.get('smoke')}, measured "
              f"smoke={measured.get('smoke')}) — not comparable, pass. "
              "Re-record the baseline under the gate's conditions.")
        return 0

    if not isinstance(m_inc, (int, float)) or m_inc <= 0:
        print("gate: FAIL — measured JSON has no events_per_sec_incremental")
        return 1
    floor = (1.0 - tolerance) * b_inc
    if m_inc < floor:
        print(f"gate: FAIL — incremental {m_inc:.0f} ev/s is below "
              f"{floor:.0f} (baseline {b_inc:.0f} - {tolerance:.0%})")
        return 1
    print(f"gate: OK — incremental {m_inc:.0f} ev/s vs baseline "
          f"{b_inc:.0f} (floor {floor:.0f})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
