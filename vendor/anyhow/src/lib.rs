//! Minimal offline stand-in for the `anyhow` crate.
//!
//! This build environment is fully offline with no vendored registry, so
//! the handful of `anyhow` APIs this workspace uses — [`Error`],
//! [`Result`], the [`anyhow!`] macro and the [`Context`] extension trait —
//! are reimplemented here and wired in as a path dependency. Error values
//! carry a flattened cause chain: `{err}` prints the top message,
//! `{err:#}` the whole chain joined with `": "`, matching anyhow's
//! alternate formatting closely enough for logs and test output.

use std::error::Error as StdError;
use std::fmt;

/// Drop-in for `anyhow::Error`: an owned error with a cause chain
/// (outermost context first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what the [`anyhow!`] macro
    /// expands to).
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error { chain: vec![msg.to_string()] }
    }

    fn from_std(err: &(dyn StdError + 'static)) -> Self {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Push a higher-level context message onto the front of the chain.
    pub fn context(mut self, msg: impl fmt::Display) -> Self {
        self.chain.insert(0, msg.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::from_std(&e)
    }
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Drop-in for `anyhow::Context`: attach context while converting into
/// [`Error`]. Implemented for `Result` over any std error and for
/// `Option` (where `None` becomes the context message).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from_std(&e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from_std(&e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Drop-in for `anyhow::anyhow!`: build an [`Error`] from a format string
/// or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($fmt:literal, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_formats() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let n = 3;
        let e = anyhow!("got {}", n);
        assert_eq!(format!("{e}"), "got 3");
        let e = anyhow!(String::from("owned"));
        assert_eq!(format!("{e}"), "owned");
    }

    #[test]
    fn context_chains_and_alternate_prints_chain() {
        let r: Result<()> = Err(io_err()).context("reading manifest");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", inner().unwrap_err()), "gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        assert_eq!(Some(1).context("unused").unwrap(), 1);
    }

    #[test]
    fn with_context_is_lazy() {
        let mut called = false;
        let ok: Result<u32, std::io::Error> = Ok(7);
        let v = ok.with_context(|| {
            called = true;
            "ctx"
        });
        assert!(matches!(v, Ok(7)));
        assert!(!called);
    }
}
